//! Backend-generic distributed EDiT sync driver.
//!
//! The trainer's own sync path simulates its cluster in-process (the
//! scratch-arena pipeline priced by the α-β model); *this* module runs
//! the same outer-round shape — inner SGD steps, reduce-scatter of the
//! pseudo-gradients, Nesterov outer update on the owned shard,
//! all-gather of the anchor — over any [`Collective`] backend, with
//! every stochastic draw stateless in `(seed, round, step, rank)`.
//! That makes it the equivalence probe for transports: the same
//! `DriverConfig` must produce a **bitwise identical final anchor**
//! whether the ranks are OS threads sharing a `ThreadComm` or OS
//! processes speaking sockets through the rendezvous hub
//! (`edit-train worker --join` vs `--local`; asserted by
//! `tests/socket_backend.rs` and `scripts/smoke_multiproc.sh`).
//!
//! # Membership degrade
//!
//! A rank that dies mid-run shrinks the group, mirroring the trainer's
//! eviction policy:
//!
//!  * reductions silently fold the live ranks (the backends' contract);
//!  * the all-gather is the detection point — a dead shard owner fails
//!    `PeerFailed`, the survivors zero its shard entry and retry, and
//!    the dead rank's region keeps its pre-round anchor values (every
//!    survivor holds the same full anchor, so the skip is consistent);
//!  * from the next round boundary, shards are rebuilt over the
//!    survivors, restoring full coverage.

use std::collections::BTreeSet;
use std::time::{Duration, Instant};

use crate::collectives::{Collective, CommError, CommHandle, CommResult, RetryPolicy, ThreadComm};
use crate::coordinator::outer::{OuterOpt, OuterOptKind};
use crate::tensor::{kernels, ShardSpec};
use crate::util::prng::{mix, Rng};

/// Which wire representation the pseudo-gradient reduce-scatter uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DriverPayload {
    /// Full-precision f32 payloads.
    #[default]
    F32,
    /// int8 codes + per-chunk scales (the `payload=int8` lane).
    Int8,
}

impl DriverPayload {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(DriverPayload::F32),
            "int8" => Some(DriverPayload::Int8),
            _ => None,
        }
    }
}

/// One distributed run's knobs. Everything that feeds a draw is here,
/// so two workers constructed from equal configs are bitwise twins.
#[derive(Debug, Clone)]
pub struct DriverConfig {
    /// Flat parameter count.
    pub params: usize,
    /// Outer rounds to run.
    pub rounds: usize,
    /// Inner SGD steps per round.
    pub inner_steps: usize,
    /// Master seed; every draw derives from it statelessly.
    pub seed: u64,
    /// Inner-loop learning rate.
    pub inner_lr: f32,
    /// Outer optimizer (paper default: Nesterov 0.8/0.85).
    pub outer: OuterOptKind,
    /// Pseudo-gradient wire representation.
    pub payload: DriverPayload,
    /// Per-collective retry/backoff policy.
    pub retry: RetryPolicy,
    /// Contiguous module count the parameter vector is split into; the
    /// round syncs module-by-module (EDiT's layer-wise shape). `1`
    /// reproduces the pre-module digests exactly.
    pub modules: usize,
    /// Issue module `m`'s collectives nonblocking and overlap them with
    /// module `m+1`'s inner compute. Bitwise identical to the blocking
    /// schedule at equal `modules`.
    pub overlap: bool,
}

impl Default for DriverConfig {
    fn default() -> Self {
        Self {
            // Odd on purpose: uneven shards and a quant-chunk remainder.
            params: 1000,
            rounds: 3,
            inner_steps: 4,
            seed: 42,
            inner_lr: 0.05,
            outer: OuterOptKind::paper_nesterov(),
            payload: DriverPayload::F32,
            retry: RetryPolicy {
                max_attempts: 5,
                base_backoff: Duration::from_millis(20),
                timeout: Duration::from_secs(5),
            },
            modules: 1,
            overlap: false,
        }
    }
}

/// What a worker ends with.
#[derive(Debug, Clone, PartialEq)]
pub struct DriverOutcome {
    /// The final synchronized anchor (identical across live ranks).
    pub anchor: Vec<f32>,
    /// FNV-1a over the anchor's raw f32 bits — the value the launcher
    /// prints and the smoke scripts diff.
    pub digest: u64,
    /// Rounds completed.
    pub rounds_done: usize,
    /// Ranks this worker observed dying, in detection order.
    pub evictions: Vec<usize>,
    /// Wall clock over all rounds (barrier to final gather).
    pub elapsed: Duration,
    /// Portion of `elapsed` spent blocked inside collective calls —
    /// issue backpressure, waits, and retries. `sync_wait / elapsed` is
    /// the measured exposed-sync fraction the bench gate compares to
    /// `StepModel::layerwise_exposed`.
    pub sync_wait: Duration,
}

/// FNV-1a over the IEEE-754 bit patterns: any single-bit anchor
/// divergence between backends changes the printed digest.
pub fn anchor_digest(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Contiguous shard table over the live ranks (ascending), dead ranks
/// pinned to `(0, 0)`. All ranks derive it from the same dead-set, so
/// the tables agree without communication.
pub fn build_shards(total: usize, world: usize, dead: &BTreeSet<usize>) -> Vec<(usize, usize)> {
    let live: Vec<usize> = (0..world).filter(|r| !dead.contains(r)).collect();
    let spec = ShardSpec::new(total, live.len().max(1));
    let mut out = vec![(0usize, 0usize); world];
    for (i, &r) in live.iter().enumerate() {
        out[r] = spec.range(i);
    }
    out
}

/// The shared initial anchor: same for every rank by construction.
fn init_anchor(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(mix(seed, 0xA17C_0000_0000_0001));
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// The rank's deterministic pseudo-gradient for one inner step of one
/// module. The module term is zero for `m = 0`, so a single-module run
/// draws exactly the historical stream.
fn grad_into(g: &mut [f32], seed: u64, rank: usize, round: usize, step: usize, module: usize) {
    let stream = ((round as u64) << 40)
        ^ ((step as u64) << 20)
        ^ ((module as u64) << 12)
        ^ (rank as u64)
        ^ 0x6772_6164_0000_0000;
    let mut rng = Rng::new(mix(seed, stream));
    for x in g.iter_mut() {
        *x = rng.normal_f32() * 0.1;
    }
}

/// Mutable per-worker round state threaded through the module schedule.
struct RoundState {
    rank: usize,
    anchor: Vec<f32>,
    theta: Vec<f32>,
    delta: Vec<f32>,
    grad: Vec<f32>,
    outer: OuterOpt,
    dead: BTreeSet<usize>,
    evictions: Vec<usize>,
    sync_wait: Duration,
}

impl RoundState {
    /// τ local SGD steps on module `m`'s slice, then the pseudo-gradient
    /// Δ_m = θ_{t,τ} − θ_t for that slice.
    fn compute_module(&mut self, cfg: &DriverConfig, round: usize, (moff, mlen): (usize, usize), m: usize) {
        let grad = &mut self.grad[moff..moff + mlen];
        let theta = &mut self.theta[moff..moff + mlen];
        for step in 0..cfg.inner_steps {
            grad_into(grad, cfg.seed, self.rank, round, step, m);
            kernels::axpy(theta, -cfg.inner_lr, grad);
        }
        for i in moff..moff + mlen {
            self.delta[i] = self.theta[i] - self.anchor[i];
        }
    }

    /// Outer update on the owned shard of module `m` (ZeRO-1 style).
    /// `folded` is the module-local delta slice whose own-shard region
    /// holds the live-group mean.
    fn outer_update(&mut self, moff: usize, folded: &[f32], shards_m: &[(usize, usize)]) {
        let (loff, llen) = shards_m[self.rank];
        self.outer.apply_range_scaled(
            &mut self.anchor,
            &folded[loff..loff + llen],
            moff + loff,
            1.0,
        );
    }

    /// Same update reading the fold result in place from `self.delta`
    /// (the blocking schedule's zero-copy path).
    fn outer_update_in_place(&mut self, moff: usize, shards_m: &[(usize, usize)]) {
        let (loff, llen) = shards_m[self.rank];
        let at = moff + loff;
        self.outer.apply_range_scaled(&mut self.anchor, &self.delta[at..at + llen], at, 1.0);
    }

    /// Evict `victim` (first detection records it) and drop its shard
    /// from this module's table so the retry skips its region.
    fn evict(&mut self, victim: usize, shards_m: &mut [(usize, usize)]) {
        if self.dead.insert(victim) {
            self.evictions.push(victim);
        }
        shards_m[victim] = (0, 0);
    }

    /// All-gather module `m`'s anchor slice — the membership detection
    /// point: a dead owner fails `PeerFailed`, the survivors evict it
    /// and retry with its shard zeroed (its region keeps the pre-round
    /// anchor on every survivor — consistent by identity).
    fn gather_module<C: Collective + ?Sized>(
        &mut self,
        comm: &C,
        cfg: &DriverConfig,
        (moff, mlen): (usize, usize),
        shards_m: &mut [(usize, usize)],
    ) -> CommResult<()> {
        let t0 = Instant::now();
        let r = loop {
            let slice = &mut self.anchor[moff..moff + mlen];
            match cfg.retry.run(|t| comm.try_all_gather(slice, shards_m, t)) {
                Ok(()) => break Ok(()),
                Err(CommError::PeerFailed { rank: victim }) => self.evict(victim, shards_m),
                Err(e) => break Err(e),
            }
        };
        self.sync_wait += t0.elapsed();
        r
    }
}

/// Issue module `m`'s pseudo-gradient reduce-scatter nonblocking.
fn issue_rs<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
    delta_m: &[f32],
    shards_m: &[(usize, usize)],
) -> CommHandle {
    let t = cfg.retry.timeout;
    match cfg.payload {
        DriverPayload::F32 => comm.start_reduce_scatter_mean(delta_m.to_vec(), shards_m, t),
        DriverPayload::Int8 => comm.start_reduce_scatter_mean_q8(delta_m.to_vec(), shards_m, t),
    }
}

/// Run one worker's rounds over `comm`. Generic over the backend —
/// this is the function both `edit-train worker --join` (SocketComm)
/// and `--local` (ThreadComm threads) execute.
pub fn run_worker<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
) -> CommResult<DriverOutcome> {
    let world = comm.size();
    let rank = comm.rank();
    let n = cfg.params;
    let modules = cfg.modules.max(1);
    let mspec = ShardSpec::new(n, modules);
    let mut st = RoundState {
        rank,
        anchor: init_anchor(n, cfg.seed),
        theta: Vec::new(),
        delta: vec![0.0f32; n],
        grad: vec![0.0f32; n],
        outer: OuterOpt::new(cfg.outer, n),
        dead: BTreeSet::new(),
        evictions: Vec::new(),
        sync_wait: Duration::ZERO,
    };
    st.theta = st.anchor.clone();
    let started = Instant::now();

    for round in 0..cfg.rounds {
        // Per-module shard tables (module-local offsets). All ranks
        // derive them from the same dead-set, so they agree.
        let mut shards: Vec<Vec<(usize, usize)>> =
            (0..modules).map(|m| build_shards(mspec.range(m).1, world, &st.dead)).collect();
        cfg.retry.run(|t| comm.try_barrier(t))?;

        if cfg.overlap {
            overlapped_round(comm, cfg, &mut st, &mspec, &mut shards, round)?;
        } else {
            for m in 0..modules {
                let (moff, mlen) = mspec.range(m);
                st.compute_module(cfg, round, (moff, mlen), m);

                // Reduce-scatter module m's pseudo-gradients: own region
                // ends with the live-group mean. A rank dying here
                // degrades silently.
                let t0 = Instant::now();
                cfg.retry.run(|t| {
                    let slice = &mut st.delta[moff..moff + mlen];
                    match cfg.payload {
                        DriverPayload::F32 => comm.try_reduce_scatter_mean(slice, &shards[m], t),
                        DriverPayload::Int8 => {
                            comm.try_reduce_scatter_mean_q8(slice, &shards[m], t)
                        }
                    }
                })?;
                st.sync_wait += t0.elapsed();

                st.outer_update_in_place(moff, &shards[m]);
                st.gather_module(comm, cfg, (moff, mlen), &mut shards[m])?;
            }
        }

        // Inner restart from the synchronized anchor.
        st.theta.copy_from_slice(&st.anchor);
    }

    let digest = anchor_digest(&st.anchor);
    Ok(DriverOutcome {
        anchor: st.anchor,
        digest,
        rounds_done: cfg.rounds,
        evictions: st.evictions,
        elapsed: started.elapsed(),
        sync_wait: st.sync_wait,
    })
}

/// The overlapped module schedule: issue module `m`'s reduce-scatter,
/// compute module `m+1` while it folds, and wait only at each
/// dependency point. At most three ops are in flight (`rs_{m}`,
/// `ag_{m-1}`, `ag_{m-2}`), inside the backends' `PIPELINE_WINDOW`.
/// Fold order and membership semantics match the blocking schedule, so
/// the result is bitwise identical.
fn overlapped_round<C: Collective + ?Sized>(
    comm: &C,
    cfg: &DriverConfig,
    st: &mut RoundState,
    mspec: &ShardSpec,
    shards: &mut [Vec<(usize, usize)>],
    round: usize,
) -> CommResult<()> {
    let modules = shards.len();
    let mut rs_h: Vec<Option<CommHandle>> = (0..modules).map(|_| None).collect();
    let mut ag_h: Vec<Option<CommHandle>> = (0..modules).map(|_| None).collect();

    // Wait for module m's reduce-scatter, apply the outer update on the
    // owned shard, and immediately issue module m's all-gather.
    fn fold_and_gather<C: Collective + ?Sized>(
        comm: &C,
        cfg: &DriverConfig,
        st: &mut RoundState,
        mspec: &ShardSpec,
        shards: &[Vec<(usize, usize)>],
        m: usize,
        rs: CommHandle,
    ) -> CommResult<CommHandle> {
        let (moff, mlen) = mspec.range(m);
        let t0 = Instant::now();
        let folded = comm.wait_handle(rs)?;
        st.sync_wait += t0.elapsed();
        st.outer_update(moff, &folded, &shards[m]);
        Ok(comm.start_all_gather(
            st.anchor[moff..moff + mlen].to_vec(),
            &shards[m],
            cfg.retry.timeout,
        ))
    }

    // Complete module m's all-gather; on PeerFailed fall back to the
    // blocking evict/zero-shard/retry loop (the anchor slice is still
    // intact — the gather operated on a copy).
    fn finish_gather<C: Collective + ?Sized>(
        comm: &C,
        cfg: &DriverConfig,
        st: &mut RoundState,
        mspec: &ShardSpec,
        shards_m: &mut [(usize, usize)],
        m: usize,
        ag: CommHandle,
    ) -> CommResult<()> {
        let (moff, mlen) = mspec.range(m);
        let t0 = Instant::now();
        match comm.wait_handle(ag) {
            Ok(buf) => {
                st.anchor[moff..moff + mlen].copy_from_slice(&buf);
                st.sync_wait += t0.elapsed();
                Ok(())
            }
            Err(CommError::PeerFailed { rank: victim }) => {
                st.sync_wait += t0.elapsed();
                st.evict(victim, shards_m);
                st.gather_module(comm, cfg, (moff, mlen), shards_m)
            }
            Err(e) => {
                st.sync_wait += t0.elapsed();
                Err(e)
            }
        }
    }

    for m in 0..modules {
        st.compute_module(cfg, round, mspec.range(m), m);
        let (moff, mlen) = mspec.range(m);
        rs_h[m] = Some(issue_rs(comm, cfg, &st.delta[moff..moff + mlen], &shards[m]));
        if m >= 1 {
            let rs = rs_h[m - 1].take().expect("rs handle issued last iteration");
            ag_h[m - 1] = Some(fold_and_gather(comm, cfg, st, mspec, shards, m - 1, rs)?);
        }
        if m >= 2 {
            let ag = ag_h[m - 2].take().expect("ag handle issued last iteration");
            finish_gather(comm, cfg, st, mspec, &mut shards[m - 2], m - 2, ag)?;
        }
    }
    // Drain the tail: rs_{M-1} → ag_{M-1}, then the last two gathers.
    let rs = rs_h[modules - 1].take().expect("tail rs handle");
    ag_h[modules - 1] = Some(fold_and_gather(comm, cfg, st, mspec, shards, modules - 1, rs)?);
    for m in modules.saturating_sub(2)..modules {
        if let Some(ag) = ag_h[m].take() {
            finish_gather(comm, cfg, st, mspec, &mut shards[m], m, ag)?;
        }
    }
    Ok(())
}

/// Run a `world`-rank group on OS threads over a shared [`ThreadComm`]
/// — the in-process reference the socket path is diffed against.
pub fn run_local_group(world: usize, cfg: &DriverConfig) -> CommResult<Vec<DriverOutcome>> {
    let comms = ThreadComm::group(world);
    let mut out = Vec::with_capacity(world);
    std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| s.spawn(move || run_worker(c, cfg)))
            .collect();
        for h in handles {
            out.push(h.join().expect("worker thread panicked"));
        }
    });
    out.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_group_ranks_agree_and_runs_reproduce() {
        let cfg = DriverConfig { params: 257, rounds: 3, ..Default::default() };
        for world in [1usize, 2, 3] {
            let a = run_local_group(world, &cfg).unwrap();
            for o in &a[1..] {
                assert_eq!(o.anchor, a[0].anchor, "world={world}");
            }
            let b = run_local_group(world, &cfg).unwrap();
            assert_eq!(a[0].digest, b[0].digest, "world={world}");
            assert!(a[0].evictions.is_empty());
        }
        // Different worlds genuinely shard differently but still sync:
        // the digest must be a function of (seed, world).
        let w2 = run_local_group(2, &cfg).unwrap();
        let w3 = run_local_group(3, &cfg).unwrap();
        assert_ne!(w2[0].digest, w3[0].digest);
    }

    #[test]
    fn int8_payload_differs_but_is_deterministic() {
        let f32cfg = DriverConfig { params: 300, ..Default::default() };
        let q8cfg = DriverConfig { payload: DriverPayload::Int8, ..f32cfg.clone() };
        let a = run_local_group(2, &f32cfg).unwrap();
        let b = run_local_group(2, &q8cfg).unwrap();
        let c = run_local_group(2, &q8cfg).unwrap();
        assert_ne!(a[0].digest, b[0].digest, "quantization must be observable");
        assert_eq!(b[0].digest, c[0].digest);
        assert_eq!(b[0].anchor, b[1].anchor);
    }

    #[test]
    fn overlapped_schedule_is_bitwise_identical() {
        for payload in [DriverPayload::F32, DriverPayload::Int8] {
            for modules in [1usize, 3, 4] {
                let blocking =
                    DriverConfig { params: 257, modules, payload, ..Default::default() };
                let overlapped = DriverConfig { overlap: true, ..blocking.clone() };
                for world in [1usize, 2, 3] {
                    let a = run_local_group(world, &blocking).unwrap();
                    let b = run_local_group(world, &overlapped).unwrap();
                    assert_eq!(
                        a[0].digest, b[0].digest,
                        "overlap changed the result: world={world} modules={modules} payload={payload:?}"
                    );
                    assert_eq!(a[0].anchor, b[0].anchor);
                }
            }
        }
    }

    #[test]
    fn single_module_layout_preserves_legacy_stream() {
        // modules=1 must draw the historical gradient stream: splitting
        // into modules only changes results when modules > 1.
        let legacy = DriverConfig { params: 300, ..Default::default() };
        let single = DriverConfig { modules: 1, ..legacy.clone() };
        let multi = DriverConfig { modules: 4, ..legacy.clone() };
        let a = run_local_group(2, &legacy).unwrap();
        let b = run_local_group(2, &single).unwrap();
        let c = run_local_group(2, &multi).unwrap();
        assert_eq!(a[0].digest, b[0].digest);
        assert_ne!(a[0].digest, c[0].digest, "module split must be observable");
    }

    #[test]
    fn dead_rank_is_evicted_and_survivors_agree() {
        // Rank 2 never shows up; a monitor marks it failed while the
        // survivors block on the first barrier — the driver must evict
        // at the all-gather and finish over the live pair.
        let cfg = DriverConfig { params: 101, rounds: 3, ..Default::default() };
        let comms = ThreadComm::group(3);
        let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
        let cfg = &cfg;
        let (a, b) = std::thread::scope(|s| {
            let h0 = s.spawn(move || run_worker(c0, cfg));
            let h1 = s.spawn(move || run_worker(c1, cfg));
            let m = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                c2.mark_failed(2);
            });
            m.join().unwrap();
            (h0.join().unwrap().unwrap(), h1.join().unwrap().unwrap())
        });
        assert_eq!(a.anchor, b.anchor);
        assert_eq!(a.evictions, vec![2]);
        assert_eq!(b.evictions, vec![2]);
    }

    #[test]
    fn dead_rank_is_evicted_under_overlap() {
        // Same scenario with in-flight handles: the PeerFailed surfaces
        // at a gather wait and the fallback evict/retry loop must leave
        // the survivors in agreement.
        let cfg = DriverConfig {
            params: 101,
            rounds: 3,
            modules: 4,
            overlap: true,
            ..Default::default()
        };
        let comms = ThreadComm::group(3);
        let (c0, c1, c2) = (&comms[0], &comms[1], &comms[2]);
        let cfg = &cfg;
        let (a, b) = std::thread::scope(|s| {
            let h0 = s.spawn(move || run_worker(c0, cfg));
            let h1 = s.spawn(move || run_worker(c1, cfg));
            let m = s.spawn(move || {
                std::thread::sleep(Duration::from_millis(50));
                c2.mark_failed(2);
            });
            m.join().unwrap();
            (h0.join().unwrap().unwrap(), h1.join().unwrap().unwrap())
        });
        assert_eq!(a.anchor, b.anchor);
        assert_eq!(a.evictions, vec![2]);
        assert_eq!(b.evictions, vec![2]);
    }
}
