//! Deterministic collective operations over rank-indexed buffers.
//!
//! These are the *numerics* of NCCL-style collectives, executed with a
//! bitwise-deterministic reduction order (rank 0..n-1 fold) so training
//! runs reproduce exactly.  The trainer calls them sequentially on the
//! worker states it owns (DESIGN.md §1: workers are simulated in one
//! process); the threaded rendezvous variant lives in [`super::thread`]
//! and shares these reference semantics.
//!
//! The `_q8` variants model the compressed payload axis
//! (`payload=int8`): each rank's contribution is quantized to int8
//! codes + per-[`QUANT_CHUNK`] f32 scales (the bytes that would travel
//! the wire), and the fold dequantizes in ascending rank order — the
//! same formulas as `tensor::kernels`' fused qdq chunk, so receiver-side
//! results are deterministic across the sequential and threaded
//! implementations.

use crate::tensor::QUANT_CHUNK;

/// Sum-reduce all buffers into every buffer (in place).
pub fn all_reduce_sum(bufs: &mut [&mut [f32]]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    debug_assert!(bufs.iter().all(|b| b.len() == len));
    // Deterministic fold into rank 0, then broadcast.
    let (first, rest) = bufs.split_first_mut().unwrap();
    for b in rest.iter() {
        for (acc, &x) in first.iter_mut().zip(b.iter()) {
            *acc += x;
        }
    }
    for b in rest.iter_mut() {
        b.copy_from_slice(first);
    }
}

/// Mean-reduce all buffers into every buffer (in place).
pub fn all_reduce_mean(bufs: &mut [&mut [f32]]) {
    let n = bufs.len();
    all_reduce_sum(bufs);
    if n > 1 {
        let inv = 1.0 / n as f32;
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
    }
}

/// All-gather: each rank contributes its shard of `full`; afterwards all
/// `full` buffers contain the concatenation. `shards[r]` gives rank r's
/// (offset, len) within the full vector.
pub fn all_gather(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    // Materialize each rank's owned shard into every other rank.
    for src in 0..n {
        let (off, len) = shards[src];
        // Copy src's shard out first (cannot alias two &mut).
        let shard: Vec<f32> = fulls[src][off..off + len].to_vec();
        for (dst, full) in fulls.iter_mut().enumerate() {
            if dst != src {
                full[off..off + len].copy_from_slice(&shard);
            }
        }
    }
}

/// Reduce-scatter (mean): sums all full buffers, then each rank keeps the
/// mean of its own shard (other regions left untouched).
pub fn reduce_scatter_mean(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    let inv = 1.0 / n as f32;
    for (dst, &(off, len)) in shards.iter().enumerate() {
        // acc = sum over all ranks of their [off..off+len] region.
        let mut acc = vec![0.0f32; len];
        for full in fulls.iter() {
            for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                *a += x;
            }
        }
        for (x, a) in fulls[dst][off..off + len].iter_mut().zip(&acc) {
            *x = a * inv;
        }
    }
}

/// Reduce-scatter (sum): like [`reduce_scatter_mean`] without the 1/n
/// scale — rank `dst`'s shard ends with the raw rank-0..n fold of that
/// region across all ranks.
pub fn reduce_scatter_sum(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    for (dst, &(off, len)) in shards.iter().enumerate() {
        let mut acc = vec![0.0f32; len];
        for full in fulls.iter() {
            for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                *a += x;
            }
        }
        fulls[dst][off..off + len].copy_from_slice(&acc);
    }
}

/// Weighted reduce-scatter: rank `dst`'s shard ends with
/// `Σ_j weights[j] · fulls[j]` over its region — the EDiT softmax-
/// weighted pseudo-gradient combine expressed as a collective. The fold
/// runs in ascending rank order with zero-weight ranks skipped, exactly
/// the accumulation the fused combine kernel
/// (`tensor::kernels::weighted_sum_sq_strided`) performs per element,
/// so the sharded sync path's shard-local combine is bitwise equal to
/// this reference.
pub fn reduce_scatter_weighted(
    fulls: &mut [&mut [f32]],
    shards: &[(usize, usize)],
    weights: &[f32],
) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    debug_assert_eq!(n, weights.len());
    if n == 0 {
        return;
    }
    for (dst, &(off, len)) in shards.iter().enumerate() {
        let mut acc = vec![0.0f32; len];
        for (full, &w) in fulls.iter().zip(weights) {
            if w != 0.0 {
                for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                    *a += w * x;
                }
            }
        }
        fulls[dst][off..off + len].copy_from_slice(&acc);
    }
}

/// Symmetric int8 per-[`QUANT_CHUNK`] quantization of a full vector —
/// the staging half of [`reduce_scatter_mean_q8`]. Scale is
/// max|v|/127 per chunk with deterministic round-to-nearest codes in
/// [-127, 127]; an all-zero chunk stays (codes 0, scale 0). Formulas
/// identical to `tensor::kernels::quant_dequant_ef`'s int8 chunk, so
/// wire payloads agree across layers. Buffers are `clear()`ed and
/// refilled — repeated calls at a size allocate nothing.
pub fn quantize_int8_into(x: &[f32], codes: &mut Vec<i8>, scales: &mut Vec<f32>) {
    codes.clear();
    codes.resize(x.len(), 0);
    scales.clear();
    scales.resize(x.len().div_ceil(QUANT_CHUNK), 0.0);
    for (c, chunk) in x.chunks(QUANT_CHUNK).enumerate() {
        let mut mx = 0.0f32;
        for &v in chunk {
            mx = mx.max(v.abs());
        }
        if mx == 0.0 {
            continue;
        }
        let scale = mx / 127.0;
        let inv = 1.0 / scale;
        scales[c] = scale;
        for (i, &v) in chunk.iter().enumerate() {
            codes[c * QUANT_CHUNK + i] = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Reduce-scatter (mean) over int8-quantized wire payloads: each rank's
/// contribution is quantized ([`quantize_int8_into`]) as it would be
/// staged on the wire, and rank `dst`'s shard ends with the mean of the
/// **dequantized** contributions (ascending-rank fold, then the 1/n
/// scale). The quantization error stays with the *sender* — callers run
/// error feedback around this op (see `coordinator::scratch`).
pub fn reduce_scatter_mean_q8(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    let mut codes: Vec<Vec<i8>> = vec![Vec::new(); n];
    let mut scales: Vec<Vec<f32>> = vec![Vec::new(); n];
    for (r, full) in fulls.iter().enumerate() {
        quantize_int8_into(full, &mut codes[r], &mut scales[r]);
    }
    let inv = 1.0 / n as f32;
    for (dst, &(off, len)) in shards.iter().enumerate() {
        for i in 0..len {
            let gi = off + i;
            let mut acc = 0.0f32;
            for r in 0..n {
                acc += codes[r][gi] as f32 * scales[r][gi / QUANT_CHUNK];
            }
            fulls[dst][gi] = acc * inv;
        }
    }
}

/// Broadcast rank `root`'s buffer to all others.
pub fn broadcast(bufs: &mut [&mut [f32]], root: usize) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let src: Vec<f32> = bufs[root].to_vec();
    for (r, b) in bufs.iter_mut().enumerate() {
        if r != root {
            b.copy_from_slice(&src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ShardSpec;

    fn make(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|r| (0..len).map(|i| (r * len + i) as f32).collect())
            .collect()
    }

    fn as_mut(bufs: &mut [Vec<f32>]) -> Vec<&mut [f32]> {
        bufs.iter_mut().map(|b| b.as_mut_slice()).collect()
    }

    #[test]
    fn all_reduce_mean_correct() {
        let mut bufs = make(4, 3);
        let expect: Vec<f32> = (0..3)
            .map(|i| (0..4).map(|r| (r * 3 + i) as f32).sum::<f32>() / 4.0)
            .collect();
        all_reduce_mean(&mut as_mut(&mut bufs));
        for b in &bufs {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn all_reduce_deterministic_order() {
        // Values chosen so f32 addition order matters: result must equal
        // the rank-0..n fold exactly.
        let mut bufs = vec![vec![1e8f32], vec![1.0], vec![-1e8], vec![1.0]];
        let expect = (((1e8f32 + 1.0) + -1e8) + 1.0) / 4.0;
        all_reduce_mean(&mut as_mut(&mut bufs));
        for b in &bufs {
            assert_eq!(b[0], expect);
        }
    }

    #[test]
    fn all_gather_assembles_shards() {
        let spec = ShardSpec::new(10, 3);
        let shards: Vec<_> = (0..3).map(|r| spec.range(r)).collect();
        // Each rank has garbage everywhere except its own shard = rank+1.
        let mut bufs: Vec<Vec<f32>> = (0..3)
            .map(|r| {
                let mut v = vec![-1.0f32; 10];
                let (off, len) = shards[r];
                v[off..off + len].fill(r as f32 + 1.0);
                v
            })
            .collect();
        all_gather(&mut as_mut(&mut bufs), &shards);
        let expect = vec![1., 1., 1., 1., 2., 2., 2., 2., 3., 3.];
        for b in &bufs {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn reduce_scatter_then_gather_is_allreduce() {
        let spec = ShardSpec::new(8, 4);
        let shards: Vec<_> = (0..4).map(|r| spec.range(r)).collect();
        let mut a = make(4, 8);
        let mut b = a.clone();

        all_reduce_mean(&mut as_mut(&mut a));
        reduce_scatter_mean(&mut as_mut(&mut b), &shards);
        all_gather(&mut as_mut(&mut b), &shards);
        for (x, y) in a.iter().zip(&b) {
            for (u, v) in x.iter().zip(y) {
                assert!((u - v).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn reduce_scatter_sum_folds_all_ranks() {
        let spec = ShardSpec::new(9, 3);
        let shards: Vec<_> = (0..3).map(|r| spec.range(r)).collect();
        let mut sum = make(3, 9);
        reduce_scatter_sum(&mut as_mut(&mut sum), &shards);
        for (r, &(off, len)) in shards.iter().enumerate() {
            for i in off..off + len {
                // make(): buf[r][i] = r*9 + i, so the fold is 27 + 3i.
                assert_eq!(sum[r][i], (27 + 3 * i) as f32, "r={r} i={i}");
            }
        }
    }

    #[test]
    fn reduce_scatter_weighted_matches_manual() {
        let spec = ShardSpec::new(10, 4); // uneven tail: 3,3,3,1
        let shards: Vec<_> = (0..4).map(|r| spec.range(r)).collect();
        let bufs = make(4, 10);
        let weights = [0.5f32, 0.0, 0.25, 0.25];
        let mut got = bufs.clone();
        reduce_scatter_weighted(&mut as_mut(&mut got), &shards, &weights);
        for (dst, &(off, len)) in shards.iter().enumerate() {
            for i in off..off + len {
                // Ascending-rank fold, zero weights skipped.
                let mut want = 0.0f32;
                for (b, &w) in bufs.iter().zip(&weights) {
                    if w != 0.0 {
                        want += w * b[i];
                    }
                }
                assert_eq!(got[dst][i], want, "dst={dst} i={i}");
            }
        }
    }

    #[test]
    fn reduce_scatter_q8_tracks_unquantized_within_chunk_bound() {
        // The q8 fold must land within the mean of the per-rank
        // half-step quantization bounds (chunk max|v|/127/2) of the
        // exact f32 reduce-scatter, element-wise. Length chosen to
        // exercise a remainder chunk.
        let n = 3usize;
        let len = 2 * QUANT_CHUNK + 17;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let make = |r: usize| -> Vec<f32> {
            (0..len)
                .map(|i| ((i * 37 + r * 101) % 255) as f32 * 0.01 - 1.2)
                .collect()
        };
        let orig: Vec<Vec<f32>> = (0..n).map(make).collect();
        let mut exact: Vec<Vec<f32>> = (0..n).map(make).collect();
        let mut quant: Vec<Vec<f32>> = (0..n).map(make).collect();
        reduce_scatter_mean(&mut as_mut(&mut exact), &shards);
        reduce_scatter_mean_q8(&mut as_mut(&mut quant), &shards);
        for (dst, &(off, dlen)) in shards.iter().enumerate() {
            for i in off..off + dlen {
                let c = i / QUANT_CHUNK;
                let mut bound = 0.0f64;
                for rank in orig.iter() {
                    let chunk = &rank[c * QUANT_CHUNK..((c + 1) * QUANT_CHUNK).min(len)];
                    let mx = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                    bound += (mx as f64 / 127.0) / 2.0;
                }
                bound = bound / n as f64 * 1.001 + 1e-9;
                let err = (exact[dst][i] as f64 - quant[dst][i] as f64).abs();
                assert!(err <= bound, "dst={dst} i={i} err={err} bound={bound}");
            }
        }
    }

    #[test]
    fn quantize_int8_zero_chunks_and_reuse() {
        let mut codes = Vec::new();
        let mut scales = Vec::new();
        let x = vec![0.0f32; QUANT_CHUNK + 3];
        quantize_int8_into(&x, &mut codes, &mut scales);
        assert_eq!(codes.len(), QUANT_CHUNK + 3);
        assert_eq!(scales, vec![0.0, 0.0]);
        assert!(codes.iter().all(|&c| c == 0));
        // Reuse with a different length: buffers resize cleanly.
        let y = vec![1.0f32; 5];
        quantize_int8_into(&y, &mut codes, &mut scales);
        assert_eq!(codes, vec![127i8; 5]);
        assert_eq!(scales.len(), 1);
        assert!((scales[0] - 1.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = make(3, 4);
        let root_copy = bufs[1].clone();
        broadcast(&mut as_mut(&mut bufs), 1);
        for b in &bufs {
            assert_eq!(b, &root_copy);
        }
    }

    #[test]
    fn single_rank_noops() {
        let mut bufs = make(1, 4);
        let orig = bufs[0].clone();
        all_reduce_mean(&mut as_mut(&mut bufs));
        broadcast(&mut as_mut(&mut bufs), 0);
        assert_eq!(bufs[0], orig);
    }
}
