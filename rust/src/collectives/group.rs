//! Deterministic collective operations over rank-indexed buffers.
//!
//! These are the *numerics* of NCCL-style collectives, executed with a
//! bitwise-deterministic reduction order (rank 0..n-1 fold) so training
//! runs reproduce exactly.  The trainer calls them sequentially on the
//! worker states it owns (DESIGN.md §1: workers are simulated in one
//! process); the threaded rendezvous variant lives in [`super::thread`]
//! and shares these reference semantics.

/// Sum-reduce all buffers into every buffer (in place).
pub fn all_reduce_sum(bufs: &mut [&mut [f32]]) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let len = bufs[0].len();
    debug_assert!(bufs.iter().all(|b| b.len() == len));
    // Deterministic fold into rank 0, then broadcast.
    let (first, rest) = bufs.split_first_mut().unwrap();
    for b in rest.iter() {
        for (acc, &x) in first.iter_mut().zip(b.iter()) {
            *acc += x;
        }
    }
    for b in rest.iter_mut() {
        b.copy_from_slice(first);
    }
}

/// Mean-reduce all buffers into every buffer (in place).
pub fn all_reduce_mean(bufs: &mut [&mut [f32]]) {
    let n = bufs.len();
    all_reduce_sum(bufs);
    if n > 1 {
        let inv = 1.0 / n as f32;
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
    }
}

/// All-gather: each rank contributes its shard of `full`; afterwards all
/// `full` buffers contain the concatenation. `shards[r]` gives rank r's
/// (offset, len) within the full vector.
pub fn all_gather(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    // Materialize each rank's owned shard into every other rank.
    for src in 0..n {
        let (off, len) = shards[src];
        // Copy src's shard out first (cannot alias two &mut).
        let shard: Vec<f32> = fulls[src][off..off + len].to_vec();
        for (dst, full) in fulls.iter_mut().enumerate() {
            if dst != src {
                full[off..off + len].copy_from_slice(&shard);
            }
        }
    }
}

/// Reduce-scatter (mean): sums all full buffers, then each rank keeps the
/// mean of its own shard (other regions left untouched).
pub fn reduce_scatter_mean(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    let inv = 1.0 / n as f32;
    for (dst, &(off, len)) in shards.iter().enumerate() {
        // acc = sum over all ranks of their [off..off+len] region.
        let mut acc = vec![0.0f32; len];
        for full in fulls.iter() {
            for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                *a += x;
            }
        }
        for (x, a) in fulls[dst][off..off + len].iter_mut().zip(&acc) {
            *x = a * inv;
        }
    }
}

/// Reduce-scatter (sum): like [`reduce_scatter_mean`] without the 1/n
/// scale — rank `dst`'s shard ends with the raw rank-0..n fold of that
/// region across all ranks.
pub fn reduce_scatter_sum(fulls: &mut [&mut [f32]], shards: &[(usize, usize)]) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    if n <= 1 {
        return;
    }
    for (dst, &(off, len)) in shards.iter().enumerate() {
        let mut acc = vec![0.0f32; len];
        for full in fulls.iter() {
            for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                *a += x;
            }
        }
        fulls[dst][off..off + len].copy_from_slice(&acc);
    }
}

/// Weighted reduce-scatter: rank `dst`'s shard ends with
/// `Σ_j weights[j] · fulls[j]` over its region — the EDiT softmax-
/// weighted pseudo-gradient combine expressed as a collective. The fold
/// runs in ascending rank order with zero-weight ranks skipped, exactly
/// the accumulation the fused combine kernel
/// (`tensor::kernels::weighted_sum_sq_strided`) performs per element,
/// so the sharded sync path's shard-local combine is bitwise equal to
/// this reference.
pub fn reduce_scatter_weighted(
    fulls: &mut [&mut [f32]],
    shards: &[(usize, usize)],
    weights: &[f32],
) {
    let n = fulls.len();
    debug_assert_eq!(n, shards.len());
    debug_assert_eq!(n, weights.len());
    if n == 0 {
        return;
    }
    for (dst, &(off, len)) in shards.iter().enumerate() {
        let mut acc = vec![0.0f32; len];
        for (full, &w) in fulls.iter().zip(weights) {
            if w != 0.0 {
                for (a, &x) in acc.iter_mut().zip(&full[off..off + len]) {
                    *a += w * x;
                }
            }
        }
        fulls[dst][off..off + len].copy_from_slice(&acc);
    }
}

/// Broadcast rank `root`'s buffer to all others.
pub fn broadcast(bufs: &mut [&mut [f32]], root: usize) {
    let n = bufs.len();
    if n <= 1 {
        return;
    }
    let src: Vec<f32> = bufs[root].to_vec();
    for (r, b) in bufs.iter_mut().enumerate() {
        if r != root {
            b.copy_from_slice(&src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ShardSpec;

    fn make(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|r| (0..len).map(|i| (r * len + i) as f32).collect())
            .collect()
    }

    fn as_mut(bufs: &mut [Vec<f32>]) -> Vec<&mut [f32]> {
        bufs.iter_mut().map(|b| b.as_mut_slice()).collect()
    }

    #[test]
    fn all_reduce_mean_correct() {
        let mut bufs = make(4, 3);
        let expect: Vec<f32> = (0..3)
            .map(|i| (0..4).map(|r| (r * 3 + i) as f32).sum::<f32>() / 4.0)
            .collect();
        all_reduce_mean(&mut as_mut(&mut bufs));
        for b in &bufs {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn all_reduce_deterministic_order() {
        // Values chosen so f32 addition order matters: result must equal
        // the rank-0..n fold exactly.
        let mut bufs = vec![vec![1e8f32], vec![1.0], vec![-1e8], vec![1.0]];
        let expect = (((1e8f32 + 1.0) + -1e8) + 1.0) / 4.0;
        all_reduce_mean(&mut as_mut(&mut bufs));
        for b in &bufs {
            assert_eq!(b[0], expect);
        }
    }

    #[test]
    fn all_gather_assembles_shards() {
        let spec = ShardSpec::new(10, 3);
        let shards: Vec<_> = (0..3).map(|r| spec.range(r)).collect();
        // Each rank has garbage everywhere except its own shard = rank+1.
        let mut bufs: Vec<Vec<f32>> = (0..3)
            .map(|r| {
                let mut v = vec![-1.0f32; 10];
                let (off, len) = shards[r];
                v[off..off + len].fill(r as f32 + 1.0);
                v
            })
            .collect();
        all_gather(&mut as_mut(&mut bufs), &shards);
        let expect = vec![1., 1., 1., 1., 2., 2., 2., 2., 3., 3.];
        for b in &bufs {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn reduce_scatter_then_gather_is_allreduce() {
        let spec = ShardSpec::new(8, 4);
        let shards: Vec<_> = (0..4).map(|r| spec.range(r)).collect();
        let mut a = make(4, 8);
        let mut b = a.clone();

        all_reduce_mean(&mut as_mut(&mut a));
        reduce_scatter_mean(&mut as_mut(&mut b), &shards);
        all_gather(&mut as_mut(&mut b), &shards);
        for (x, y) in a.iter().zip(&b) {
            for (u, v) in x.iter().zip(y) {
                assert!((u - v).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn reduce_scatter_sum_folds_all_ranks() {
        let spec = ShardSpec::new(9, 3);
        let shards: Vec<_> = (0..3).map(|r| spec.range(r)).collect();
        let mut sum = make(3, 9);
        reduce_scatter_sum(&mut as_mut(&mut sum), &shards);
        for (r, &(off, len)) in shards.iter().enumerate() {
            for i in off..off + len {
                // make(): buf[r][i] = r*9 + i, so the fold is 27 + 3i.
                assert_eq!(sum[r][i], (27 + 3 * i) as f32, "r={r} i={i}");
            }
        }
    }

    #[test]
    fn reduce_scatter_weighted_matches_manual() {
        let spec = ShardSpec::new(10, 4); // uneven tail: 3,3,3,1
        let shards: Vec<_> = (0..4).map(|r| spec.range(r)).collect();
        let bufs = make(4, 10);
        let weights = [0.5f32, 0.0, 0.25, 0.25];
        let mut got = bufs.clone();
        reduce_scatter_weighted(&mut as_mut(&mut got), &shards, &weights);
        for (dst, &(off, len)) in shards.iter().enumerate() {
            for i in off..off + len {
                // Ascending-rank fold, zero weights skipped.
                let mut want = 0.0f32;
                for (b, &w) in bufs.iter().zip(&weights) {
                    if w != 0.0 {
                        want += w * b[i];
                    }
                }
                assert_eq!(got[dst][i], want, "dst={dst} i={i}");
            }
        }
    }

    #[test]
    fn broadcast_copies_root() {
        let mut bufs = make(3, 4);
        let root_copy = bufs[1].clone();
        broadcast(&mut as_mut(&mut bufs), 1);
        for b in &bufs {
            assert_eq!(b, &root_copy);
        }
    }

    #[test]
    fn single_rank_noops() {
        let mut bufs = make(1, 4);
        let orig = bufs[0].clone();
        all_reduce_mean(&mut as_mut(&mut bufs));
        broadcast(&mut as_mut(&mut bufs), 0);
        assert_eq!(bufs[0], orig);
    }
}
