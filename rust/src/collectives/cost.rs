//! α-β communication cost model over a hierarchical (node/GPU) topology.
//!
//! Shared by BOTH paths (DESIGN.md §5): the numerics trainer accumulates
//! simulated wall-time per collective through this model, and the
//! analytic cluster simulator uses the very same formulas for the A100
//! throughput tables — so the timing assumptions are identical.
//!
//! Formulas are the standard ring-algorithm costs (Thakur et al.):
//!   all-reduce      2 (n-1)/n * B / bw + 2 (n-1) a
//!   all-gather        (n-1)/n * B / bw +   (n-1) a
//!   reduce-scatter    (n-1)/n * B / bw +   (n-1) a
//!   broadcast                   B / bw +         a      (tree depth folded into a)
//! where B is the FULL vector size in bytes, bw the bottleneck link
//! bandwidth and a the per-hop latency.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollOp {
    AllReduce,
    AllGather,
    ReduceScatter,
    Broadcast,
    /// Scalar control-plane exchange (penalty norms): latency only.
    ScalarSync,
}

/// Physical cluster description (calibration defaults: A100 nodes).
#[derive(Debug, Clone, Copy)]
pub struct Topology {
    pub gpus_per_node: usize,
    /// Intra-node (NVLink) per-GPU bandwidth, bytes/s.
    pub intra_bw: f64,
    /// Inter-node (IB) per-GPU bandwidth, bytes/s.
    pub inter_bw: f64,
    /// Per-hop latencies, seconds.
    pub intra_lat: f64,
    pub inter_lat: f64,
}

impl Topology {
    /// 8xA100 nodes: NVLink3 ~300 GB/s effective per-GPU bus bandwidth,
    /// 4x200 Gb/s HDR IB per node shared by 8 GPUs ~ 12.5 GB/s per GPU.
    pub fn a100() -> Self {
        Self {
            gpus_per_node: 8,
            intra_bw: 300e9,
            inter_bw: 12.5e9,
            intra_lat: 5e-6,
            inter_lat: 20e-6,
        }
    }

    /// Uniform single-level topology (useful in unit tests).
    pub fn flat(bw: f64, lat: f64) -> Self {
        Self {
            gpus_per_node: usize::MAX,
            intra_bw: bw,
            inter_bw: bw,
            intra_lat: lat,
            inter_lat: lat,
        }
    }

    pub fn node_of(&self, rank: usize) -> usize {
        if self.gpus_per_node == usize::MAX { 0 } else { rank / self.gpus_per_node }
    }

    fn spans_nodes(&self, ranks: &[usize]) -> bool {
        ranks
            .windows(2)
            .any(|w| self.node_of(w[0]) != self.node_of(w[1]))
    }

    /// Bottleneck (bandwidth, latency) for a group of global ranks.
    pub fn link(&self, ranks: &[usize]) -> (f64, f64) {
        if self.spans_nodes(ranks) {
            (self.inter_bw, self.inter_lat)
        } else {
            (self.intra_bw, self.intra_lat)
        }
    }
}

/// Cost model with an optional inter-node bandwidth derate (the paper's
/// "limited bandwidth" scenario repeats inter-node communications
/// `repeat+1` times — Fig. 5c / Table 6).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub topo: Topology,
    /// Inter-node communications are repeated this many extra times.
    pub inter_repeat: u32,
}

impl CostModel {
    pub fn new(topo: Topology) -> Self {
        Self { topo, inter_repeat: 0 }
    }

    pub fn with_inter_repeat(mut self, repeat: u32) -> Self {
        self.inter_repeat = repeat;
        self
    }

    /// Simulated seconds for `op` over `bytes` (full-vector bytes) within
    /// the group of `ranks`.
    pub fn time(&self, op: CollOp, bytes: usize, ranks: &[usize]) -> f64 {
        let n = ranks.len().max(1) as f64;
        let (bw, lat) = self.topo.link(ranks);
        let spans = self.topo.spans_nodes(ranks);
        let rep = if spans { (self.inter_repeat + 1) as f64 } else { 1.0 };
        let b = bytes as f64;
        let t = match op {
            CollOp::AllReduce => 2.0 * (n - 1.0) / n * b / bw + 2.0 * (n - 1.0) * lat,
            CollOp::AllGather | CollOp::ReduceScatter => {
                (n - 1.0) / n * b / bw + (n - 1.0) * lat
            }
            CollOp::Broadcast => b / bw + lat,
            CollOp::ScalarSync => (n - 1.0).max(1.0) * lat,
        };
        t * rep
    }

    /// [`Self::time`] for a payload of `elems` f32 elements travelling
    /// at `payload`'s wire width (codes + per-chunk scales for the
    /// quantized kinds — see `tensor::kernels::PayloadKind::wire_bytes`).
    /// For `PayloadKind::F32` this is exactly `time(op, elems * 4, ..)`,
    /// so f32 plans price bitwise like the historical byte expression.
    pub fn payload_time(
        &self,
        op: CollOp,
        elems: usize,
        payload: crate::tensor::PayloadKind,
        ranks: &[usize],
    ) -> f64 {
        self.time(op, payload.wire_bytes(elems), ranks)
    }
}

/// Per-op byte/time accounting, accumulated by the trainer.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub ops: usize,
    pub bytes: usize,
    pub seconds: f64,
}

impl CommStats {
    pub fn record(&mut self, bytes: usize, seconds: f64) {
        self.ops += 1;
        self.bytes += bytes;
        self.seconds += seconds;
    }

    pub fn merge(&mut self, other: &CommStats) {
        self.ops += other.ops;
        self.bytes += other.bytes;
        self.seconds += other.seconds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intra_vs_inter_detection() {
        let t = Topology::a100();
        assert!(!t.spans_nodes(&[0, 1, 7]));
        assert!(t.spans_nodes(&[7, 8]));
        assert_eq!(t.node_of(15), 1);
    }

    #[test]
    fn allreduce_scales_with_bytes() {
        let m = CostModel::new(Topology::flat(1e9, 0.0));
        let ranks = [0, 1, 2, 3];
        let t1 = m.time(CollOp::AllReduce, 1_000_000, &ranks);
        let t2 = m.time(CollOp::AllReduce, 2_000_000, &ranks);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn allreduce_is_two_phases_of_allgather() {
        let m = CostModel::new(Topology::flat(1e9, 0.0));
        let ranks = [0, 1, 2, 3];
        let ar = m.time(CollOp::AllReduce, 1 << 20, &ranks);
        let ag = m.time(CollOp::AllGather, 1 << 20, &ranks);
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn reduce_scatter_plus_allgather_equals_allreduce_bitwise() {
        // The identity the sharded outer sync path's pricing relies on:
        // ring reduce-scatter + ring all-gather decompose a ring
        // all-reduce exactly, and because scaling by 2 commutes with
        // IEEE rounding the α-β formulas agree BITWISE, not just
        // approximately. `CommPlan` prices the sharded per-module
        // exchange as RS+AG and stays bitwise comparable to the
        // unsharded all-reduce plan (tests/scheduler_determinism.rs).
        for topo in [Topology::a100(), Topology::flat(7.3e9, 1.9e-6)] {
            for reps in [0u32, 3] {
                let m = CostModel::new(topo).with_inter_repeat(reps);
                for bytes in [1usize, 4, 1337, 1 << 20, 123_456_789] {
                    for ranks in [vec![0, 1], vec![0, 1, 2], (0..16).collect::<Vec<_>>()] {
                        let ar = m.time(CollOp::AllReduce, bytes, &ranks);
                        let rs = m.time(CollOp::ReduceScatter, bytes, &ranks);
                        let ag = m.time(CollOp::AllGather, bytes, &ranks);
                        assert_eq!(
                            (rs + ag).to_bits(),
                            ar.to_bits(),
                            "bytes={bytes} n={} reps={reps}",
                            ranks.len()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn latency_dominates_scalar_sync() {
        let m = CostModel::new(Topology::a100());
        let t = m.time(CollOp::ScalarSync, 4, &[0, 8, 16]);
        assert!((t - 2.0 * 20e-6).abs() < 1e-12);
    }

    #[test]
    fn inter_repeat_multiplies_inter_only() {
        let m = CostModel::new(Topology::a100()).with_inter_repeat(3);
        let intra = m.time(CollOp::Broadcast, 1 << 20, &[0, 1]);
        let base = CostModel::new(Topology::a100());
        assert_eq!(intra, base.time(CollOp::Broadcast, 1 << 20, &[0, 1]));
        let inter = m.time(CollOp::Broadcast, 1 << 20, &[0, 8]);
        assert!((inter / base.time(CollOp::Broadcast, 1 << 20, &[0, 8]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn payload_time_tracks_wire_bytes() {
        use crate::tensor::PayloadKind;
        let m = CostModel::new(Topology::flat(1e9, 0.0));
        let ranks = [0, 1, 2, 3];
        let elems = 1 << 20;
        let f = m.payload_time(CollOp::AllReduce, elems, PayloadKind::F32, &ranks);
        let q = m.payload_time(CollOp::AllReduce, elems, PayloadKind::Int8, &ranks);
        let b = m.payload_time(CollOp::AllReduce, elems, PayloadKind::Bit1, &ranks);
        // f32 is the plain byte expression, bitwise.
        assert_eq!(
            f.to_bits(),
            m.time(CollOp::AllReduce, elems * 4, &ranks).to_bits()
        );
        // Zero latency ⇒ time ratio equals the wire-byte ratio exactly.
        let ratio = f / q;
        let byte_ratio = (elems * 4) as f64
            / PayloadKind::Int8.wire_bytes(elems) as f64;
        assert!((ratio - byte_ratio).abs() < 1e-9, "{ratio} vs {byte_ratio}");
        assert!(ratio >= 3.5, "int8 must cut wire time >= 3.5x, got {ratio}");
        assert!(b < q, "bit1 must be cheaper than int8");
    }

    #[test]
    fn single_rank_group_free_bandwidth() {
        let m = CostModel::new(Topology::a100());
        assert_eq!(m.time(CollOp::AllReduce, 1 << 20, &[3]), 0.0);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = CommStats::default();
        s.record(10, 0.5);
        s.record(20, 0.25);
        assert_eq!(s.ops, 2);
        assert_eq!(s.bytes, 30);
        assert!((s.seconds - 0.75).abs() < 1e-12);
    }
}
