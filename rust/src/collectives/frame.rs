//! Length-prefixed, versioned wire frames for the socket transport.
//!
//! This module is the byte-level half of the transport; the normative
//! spec (grammar, handshake sequence, fold-order contract) lives in
//! `docs/WIRE_PROTOCOL.md` and the implementation cites it per section.
//!
//! Every message on a transport connection is one **frame**
//! (WIRE_PROTOCOL.md §2):
//!
//! ```text
//! offset  size  field
//!      0     4  magic            b"EDTF"
//!      4     4  protocol version u32 LE (PROTOCOL_VERSION)
//!      8     1  frame type       u8 (FrameKind)
//!      9     4  sender rank      u32 LE (RANK_UNASSIGNED before Welcome)
//!     13     8  generation       u64 LE (membership epoch)
//!     21     4  payload length   u32 LE
//!     25     …  payload          frame-type-specific (§3)
//! ```
//!
//! Version negotiation is strict equality: the rendezvous service
//! answers a `Hello` whose version field differs from its own with an
//! `Error(VersionMismatch)` frame and closes the connection (§4.1).
//! Frames are read with [`read_frame`], which validates magic and
//! bounds the payload length before allocating.
//!
//! Integers and floats are little-endian throughout; f32 payloads are
//! raw IEEE-754 bit patterns, so a vector survives the wire bitwise.

use std::io::{self, Read, Write};

/// Frame magic: "EDiT Frame".
pub const MAGIC: [u8; 4] = *b"EDTF";
/// Protocol version spoken by this build (strict-equality negotiation).
/// v2 added the reconnect/late-join handshake payloads on Hello and
/// Welcome (WIRE_PROTOCOL.md §6).
pub const PROTOCOL_VERSION: u32 = 2;
/// Sender rank before the Welcome assignment.
pub const RANK_UNASSIGNED: u32 = u32::MAX;
/// Upper bound on a frame payload (1 GiB) — rejects corrupt lengths
/// before they become allocations.
pub const MAX_PAYLOAD: usize = 1 << 30;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 25;

/// Frame discriminants (WIRE_PROTOCOL.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Client → hub: join request. Empty payload = fresh join; a
    /// reconnecting worker sends `{rank u32, generation u64, last_seq
    /// u64}` instead (§6.1). The header's version field is the
    /// negotiation.
    Hello = 1,
    /// Hub → client: rank assignment (payload: rank u32, world u32,
    /// start_seq u64 — nonzero only for a mid-run joiner, §6.3).
    Welcome = 2,
    /// Client → hub: one collective contribution (payload: op header +
    /// operand bytes).
    Contribute = 3,
    /// Hub → client: the completed collective's result for this rank
    /// (payload: seq u64, live-mask u64, data).
    Result = 4,
    /// Either direction: a failed operation (payload: seq u64, code u8,
    /// rank u32, message).
    Error = 5,
    /// Client → hub: liveness beacon (empty payload).
    Heartbeat = 6,
    /// Client → hub: graceful leave after the last collective (empty).
    Goodbye = 7,
    /// Hub → client: the service is tearing down (empty).
    Shutdown = 8,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Welcome,
            3 => FrameKind::Contribute,
            4 => FrameKind::Result,
            5 => FrameKind::Error,
            6 => FrameKind::Heartbeat,
            7 => FrameKind::Goodbye,
            8 => FrameKind::Shutdown,
            _ => return None,
        })
    }
}

/// Collective op codes inside a Contribute payload (WIRE_PROTOCOL.md §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum OpCode {
    Barrier = 0,
    AllReduceMean = 1,
    AllGather = 2,
    ReduceScatterMean = 3,
    ReduceScatterSum = 4,
    ReduceScatterWeighted = 5,
    ReduceScatterMeanQ8 = 6,
    Broadcast = 7,
}

impl OpCode {
    pub fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            0 => OpCode::Barrier,
            1 => OpCode::AllReduceMean,
            2 => OpCode::AllGather,
            3 => OpCode::ReduceScatterMean,
            4 => OpCode::ReduceScatterSum,
            5 => OpCode::ReduceScatterWeighted,
            6 => OpCode::ReduceScatterMeanQ8,
            7 => OpCode::Broadcast,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            OpCode::Barrier => "barrier",
            OpCode::AllReduceMean => "all_reduce_mean",
            OpCode::AllGather => "all_gather",
            OpCode::ReduceScatterMean => "reduce_scatter_mean",
            OpCode::ReduceScatterSum => "reduce_scatter_sum",
            OpCode::ReduceScatterWeighted => "reduce_scatter_weighted",
            OpCode::ReduceScatterMeanQ8 => "reduce_scatter_mean_q8",
            OpCode::Broadcast => "broadcast",
        }
    }
}

/// Error codes inside an Error payload (WIRE_PROTOCOL.md §3.5). They
/// map one-to-one onto the in-process `CommError` taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// The rendezvous window elapsed without a full live quorum;
    /// retryable (`CommError::Timeout`).
    Timeout = 0,
    /// A rank the op cannot complete without is dead; deterministic
    /// (`CommError::PeerFailed`).
    PeerFailed = 1,
    /// The service is tearing down; terminal (`CommError::Shutdown`).
    Shutdown = 2,
    /// The peer violated the protocol (op/seq/meta mismatch); terminal.
    Protocol = 3,
    /// Hello carried a different PROTOCOL_VERSION; terminal.
    VersionMismatch = 4,
}

impl ErrorCode {
    pub fn from_u8(b: u8) -> Option<Self> {
        Some(match b {
            0 => ErrorCode::Timeout,
            1 => ErrorCode::PeerFailed,
            2 => ErrorCode::Shutdown,
            3 => ErrorCode::Protocol,
            4 => ErrorCode::VersionMismatch,
            _ => return None,
        })
    }
}

/// One decoded frame (header + raw payload bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub rank: u32,
    pub generation: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: FrameKind, rank: u32, generation: u64, payload: Vec<u8>) -> Self {
        Self { kind, rank, generation, payload }
    }

    /// Total bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        HEADER_LEN + self.payload.len()
    }
}

/// Serialize `frame` onto `w` (single buffered write: header + payload).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> io::Result<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + frame.payload.len());
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
    buf.push(frame.kind as u8);
    buf.extend_from_slice(&frame.rank.to_le_bytes());
    buf.extend_from_slice(&frame.generation.to_le_bytes());
    buf.extend_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&frame.payload);
    w.write_all(&buf)
}

/// Read and validate one frame. Fails with `InvalidData` on bad magic,
/// an unknown frame type, an oversized payload, or (by default) a
/// protocol-version mismatch; the rendezvous service reads the raw
/// version via [`read_frame_negotiating`] instead so it can answer a
/// mismatched Hello with `Error(VersionMismatch)`.
pub fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let (version, frame) = read_frame_negotiating(r)?;
    if version != PROTOCOL_VERSION {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("protocol version mismatch: got {version}, want {PROTOCOL_VERSION}"),
        ));
    }
    Ok(frame)
}

/// [`read_frame`] variant that surfaces the peer's version instead of
/// rejecting a mismatch, so the callee can negotiate (§4.1).
pub fn read_frame_negotiating(r: &mut impl Read) -> io::Result<(u32, Frame)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    if header[0..4] != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame magic"));
    }
    let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
    let kind = FrameKind::from_u8(header[8]).ok_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidData, format!("unknown frame type {}", header[8]))
    })?;
    let rank = u32::from_le_bytes(header[9..13].try_into().unwrap());
    let generation = u64::from_le_bytes(header[13..21].try_into().unwrap());
    let len = u32::from_le_bytes(header[21..25].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame payload length {len} exceeds MAX_PAYLOAD"),
        ));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok((version, Frame { kind, rank, generation, payload }))
}

// ---------------------------------------------------------------------------
// Payload codec helpers
// ---------------------------------------------------------------------------

/// Append-only payload writer (thin sugar over `Vec<u8>`).
#[derive(Default)]
pub struct PayloadWriter {
    pub buf: Vec<u8>,
}

impl PayloadWriter {
    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }
    /// f32 slice as raw IEEE-754 bits, prefixed with its element count.
    pub fn f32s(&mut self, xs: &[f32]) -> &mut Self {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }
    /// i8 slice, prefixed with its element count.
    pub fn i8s(&mut self, xs: &[i8]) -> &mut Self {
        self.u32(xs.len() as u32);
        self.buf.extend(xs.iter().map(|&c| c as u8));
        self
    }
    /// Shard table: count, then (offset, len) pairs as u64s.
    pub fn shards(&mut self, shards: &[(usize, usize)]) -> &mut Self {
        self.u32(shards.len() as u32);
        for &(off, len) in shards {
            self.u64(off as u64).u64(len as u64);
        }
        self
    }
    /// Length-prefixed UTF-8 string (u16 length; truncated if longer).
    pub fn text(&mut self, s: &str) -> &mut Self {
        let bytes = s.as_bytes();
        let n = bytes.len().min(u16::MAX as usize);
        self.buf.extend_from_slice(&(n as u16).to_le_bytes());
        self.buf.extend_from_slice(&bytes[..n]);
        self
    }
    pub fn finish(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }
}

/// Cursor-style payload reader; every accessor bounds-checks and fails
/// with `InvalidData` instead of panicking on truncated input.
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "truncated frame payload"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let bytes = self.take(n * 4)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    /// Like [`Self::f32s`] but decodes into `out` (cleared first).
    pub fn f32s_into(&mut self, out: &mut Vec<f32>) -> io::Result<()> {
        let n = self.u32()? as usize;
        let bytes = self.take(n * 4)?;
        out.clear();
        out.extend(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
        Ok(())
    }
    pub fn i8s(&mut self) -> io::Result<Vec<i8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }
    pub fn shards(&mut self) -> io::Result<Vec<(usize, usize)>> {
        let n = self.u32()? as usize;
        // Bound the allocation by the bytes actually present: a corrupt
        // count must fail as truncation, not reserve n*16 bytes.
        if n.checked_mul(16).is_none_or(|b| b > self.remaining()) {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "truncated frame payload"));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let off = self.u64()? as usize;
            let len = self.u64()? as usize;
            out.push((off, len));
        }
        Ok(out)
    }
    pub fn text(&mut self) -> io::Result<String> {
        let n = u16::from_le_bytes(self.take(2)?.try_into().unwrap()) as usize;
        Ok(String::from_utf8_lossy(self.take(n)?).into_owned())
    }
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Incremental frame assembler for timeout-polled sockets.
///
/// `read_exact` under a read timeout can fail *mid-frame* after
/// consuming part of the header, losing the frame boundary. This
/// assembler only ever appends whatever one `read()` returns and parses
/// complete frames off the front, so a timeout between bytes never
/// desynchronizes the stream.
#[derive(Default)]
pub struct FrameBuffer {
    buf: Vec<u8>,
    chunk: Vec<u8>,
}

impl FrameBuffer {
    pub fn new() -> Self {
        Self { buf: Vec::new(), chunk: vec![0u8; 64 * 1024] }
    }

    /// Parse one complete frame off the front of the buffer, if present.
    /// Returns the peer's protocol version alongside the frame (callers
    /// negotiate; see [`read_frame_negotiating`]).
    pub fn poll(&mut self) -> io::Result<Option<(u32, Frame)>> {
        if self.buf.len() < HEADER_LEN {
            return Ok(None);
        }
        if self.buf[0..4] != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad frame magic"));
        }
        let len = u32::from_le_bytes(self.buf[21..25].try_into().unwrap()) as usize;
        if len > MAX_PAYLOAD {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "oversized frame payload"));
        }
        if self.buf.len() < HEADER_LEN + len {
            return Ok(None);
        }
        let frame_bytes: Vec<u8> = self.buf.drain(..HEADER_LEN + len).collect();
        read_frame_negotiating(&mut frame_bytes.as_slice()).map(Some)
    }

    /// Append whatever one `read()` call yields. Returns the byte count
    /// (0 = EOF); timeout errors (`WouldBlock`/`TimedOut`) pass through
    /// for the caller's idle handling.
    pub fn fill_from(&mut self, r: &mut impl Read) -> io::Result<usize> {
        let n = r.read(&mut self.chunk)?;
        self.buf.extend_from_slice(&self.chunk[..n]);
        Ok(n)
    }

    /// Discard any partially assembled bytes. A reconnecting client
    /// must call this when it swaps streams: the tail of the old
    /// connection is not a frame prefix on the new one (§6.1).
    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let mut p = PayloadWriter::default();
        p.u8(7).u64(42).f32s(&[1.5, -0.0, f32::MIN_POSITIVE]).shards(&[(0, 3), (3, 2)]);
        let frame = Frame::new(FrameKind::Contribute, 2, 9, p.finish());
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        assert_eq!(wire.len(), frame.wire_len());
        let got = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!(got, frame);
        let mut r = PayloadReader::new(&got.payload);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u64().unwrap(), 42);
        let xs = r.f32s().unwrap();
        // Bitwise: -0.0 must survive the wire as -0.0.
        assert_eq!(xs[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(xs[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.shards().unwrap(), vec![(0, 3), (3, 2)]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn bad_magic_rejected() {
        let frame = Frame::new(FrameKind::Hello, RANK_UNASSIGNED, 0, Vec::new());
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        wire[0] = b'X';
        assert!(read_frame(&mut wire.as_slice()).is_err());
    }

    #[test]
    fn version_mismatch_rejected_but_negotiable() {
        let frame = Frame::new(FrameKind::Hello, RANK_UNASSIGNED, 0, Vec::new());
        let mut wire = Vec::new();
        write_frame(&mut wire, &frame).unwrap();
        wire[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(read_frame(&mut wire.as_slice()).is_err());
        let (v, f) = read_frame_negotiating(&mut wire.as_slice()).unwrap();
        assert_eq!(v, 99);
        assert_eq!(f.kind, FrameKind::Hello);
    }

    #[test]
    fn truncated_payload_errors_cleanly() {
        let mut p = PayloadWriter::default();
        p.f32s(&[1.0, 2.0]);
        let payload = p.finish();
        let mut r = PayloadReader::new(&payload[..5]);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&MAGIC);
        wire.extend_from_slice(&PROTOCOL_VERSION.to_le_bytes());
        wire.push(FrameKind::Hello as u8);
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&0u64.to_le_bytes());
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        assert!(read_frame(&mut wire.as_slice()).is_err());
    }
}
