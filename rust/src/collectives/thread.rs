//! Rendezvous-based threaded communicator.
//!
//! The real-concurrency counterpart of [`super::group`]: N OS threads
//! (one per simulated worker) meet at a staging area + barrier, exactly
//! like an NCCL communicator.  Reduction order is the same deterministic
//! rank-0..n fold as the sequential reference, and the test suite
//! asserts bitwise equality between both implementations.
//!
//! Concurrency layout (the striped rework): contributions live in
//! per-rank `RwLock` slots, so staging takes one short uncontended write
//! lock and the reduce phase reads every slot *in parallel* instead of
//! serializing all ranks behind a single staging mutex.  For the
//! all-reduce, each rank folds only its own contiguous stripe
//! (`ShardSpec` split) into a shared stripe slab and then gathers every
//! stripe — ring-style bandwidth parallelism with the sequential fold
//! order preserved per element, so results stay bitwise equal to
//! [`super::group::all_reduce_mean`].
//!
//! Steady-state allocation: every slot (staging and stripe) is a `Vec`
//! that is `clear()`ed and refilled, so repeated collectives reuse their
//! capacity and allocate nothing after the first round at a given size.
//!
//! The numerics trainer runs single-threaded (PJRT client is not Send,
//! and this box has one core), so this module is exercised by tests,
//! benches, and any future multi-process deployment of the coordinator.

use std::sync::{Arc, Barrier, RwLock};

use crate::tensor::{kernels, ShardSpec};

struct Inner {
    n: usize,
    /// Per-rank contribution slots.
    staging: Vec<RwLock<Vec<f32>>>,
    /// Per-rank reduced-stripe slots (all-reduce slab).
    stripes: Vec<RwLock<Vec<f32>>>,
    barrier: Barrier,
}

/// Per-rank handle; clone-free — create one set via [`ThreadComm::group`].
pub struct ThreadComm {
    rank: usize,
    inner: Arc<Inner>,
}

impl ThreadComm {
    /// Create handles for an `n`-rank group.
    pub fn group(n: usize) -> Vec<ThreadComm> {
        let inner = Arc::new(Inner {
            n,
            staging: (0..n).map(|_| RwLock::new(Vec::new())).collect(),
            stripes: (0..n).map(|_| RwLock::new(Vec::new())).collect(),
            barrier: Barrier::new(n),
        });
        (0..n).map(|rank| ThreadComm { rank, inner: Arc::clone(&inner) }).collect()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.inner.n
    }

    pub fn barrier(&self) {
        self.inner.barrier.wait();
    }

    fn stage(&self, data: &[f32]) {
        let mut slot = self.inner.staging[self.rank].write().unwrap();
        slot.clear();
        slot.extend_from_slice(data);
    }

    /// Mean all-reduce across the group (every rank ends with the mean).
    ///
    /// Striped: rank r sums ranks' contributions over stripe r only
    /// (fold order 0..n, then the 1/n scale — per element exactly the
    /// sequential reference's operation sequence), publishes the stripe,
    /// and gathers the other stripes after the barrier.
    pub fn all_reduce_mean(&self, buf: &mut [f32]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(buf);
        self.inner.barrier.wait();

        let spec = ShardSpec::new(buf.len(), n);
        let inv = 1.0 / n as f32;
        {
            let (off, len) = spec.range(self.rank);
            let mut stripe = self.inner.stripes[self.rank].write().unwrap();
            stripe.clear();
            {
                let s0 = self.inner.staging[0].read().unwrap();
                stripe.extend_from_slice(&s0[off..off + len]);
            }
            for r in 1..n {
                let sr = self.inner.staging[r].read().unwrap();
                kernels::add(&mut stripe[..], &sr[off..off + len]);
            }
            kernels::scale(&mut stripe[..], inv);
        }
        // All stripes reduced before anyone gathers.
        self.inner.barrier.wait();
        for r in 0..n {
            let (off, len) = spec.range(r);
            let sr = self.inner.stripes[r].read().unwrap();
            buf[off..off + len].copy_from_slice(&sr);
        }
        // Nobody restages (or re-reduces into a stripe) until all have read.
        self.inner.barrier.wait();
    }

    /// All-gather: each rank contributes `full[shards[rank]]`; on return
    /// `full` holds every shard. `shards[r] = (offset, len)`.
    pub fn all_gather(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        if self.inner.n == 1 {
            return;
        }
        let (off, len) = shards[self.rank];
        self.stage(&full[off..off + len]);
        self.inner.barrier.wait();
        for (r, &(o, l)) in shards.iter().enumerate() {
            if r != self.rank {
                let sr = self.inner.staging[r].read().unwrap();
                full[o..o + l].copy_from_slice(&sr);
            }
        }
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (mean): on return this rank's shard region holds the
    /// group mean of that region; the rest of `full` is untouched.  Each
    /// rank folds only its own shard, reading the per-rank slots in
    /// parallel (fold order 0..n preserved).
    pub fn reduce_scatter_mean(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        {
            let s0 = self.inner.staging[0].read().unwrap();
            full[off..off + len].copy_from_slice(&s0[off..off + len]);
        }
        for r in 1..n {
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        kernels::scale(&mut full[off..off + len], 1.0 / n as f32);
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (sum): like [`Self::reduce_scatter_mean`] without
    /// the 1/n scale — rank-0..n fold order, bitwise equal to
    /// [`super::group::reduce_scatter_sum`]. The fold starts from a
    /// zero-initialized accumulator and adds every rank including rank
    /// 0, exactly like the reference (seeding by copying rank 0's shard
    /// would diverge bitwise on -0.0 inputs).
    pub fn reduce_scatter_sum(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..n {
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        self.inner.barrier.wait();
    }

    /// Weighted reduce-scatter: this rank's shard ends with
    /// `Σ_j weights[j]·x_j` over its region (ascending-rank fold,
    /// zero-weight ranks skipped — bitwise equal to
    /// [`super::group::reduce_scatter_weighted`]).
    pub fn reduce_scatter_weighted(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
    ) {
        let n = self.inner.n;
        debug_assert_eq!(n, weights.len());
        if n == 1 {
            // Degenerate group: reproduce the reference's zero-init +
            // single-fold accumulation exactly (incl. the -0.0 edge).
            let (off, len) = shards[self.rank];
            let w = weights[0];
            for x in full[off..off + len].iter_mut() {
                let mut acc = 0.0f32;
                if w != 0.0 {
                    acc += w * *x;
                }
                *x = acc;
            }
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for (r, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                let sr = self.inner.staging[r].read().unwrap();
                kernels::axpy(&mut full[off..off + len], w, &sr[off..off + len]);
            }
        }
        self.inner.barrier.wait();
    }

    /// Broadcast `root`'s buffer to every rank.
    pub fn broadcast(&self, buf: &mut [f32], root: usize) {
        if self.inner.n == 1 {
            return;
        }
        if self.rank == root {
            self.stage(buf);
        }
        self.inner.barrier.wait();
        if self.rank != root {
            let slot = self.inner.staging[root].read().unwrap();
            buf.copy_from_slice(&slot);
        }
        self.inner.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::group;
    use crate::tensor::ShardSpec;

    fn run_threads<F>(n: usize, len: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&ThreadComm, &mut Vec<f32>) + Send + Sync,
    {
        let comms = ThreadComm::group(n);
        let mut out = vec![Vec::new(); n];
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    s.spawn(move || {
                        let mut buf: Vec<f32> =
                            (0..len).map(|i| (comm.rank() * len + i) as f32).collect();
                        f(&comm, &mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                out[r] = h.join().unwrap();
            }
        });
        out
    }

    #[test]
    fn threaded_allreduce_matches_sequential() {
        let n = 4;
        let len = 37;
        let got = run_threads(n, len, |c, buf| c.all_reduce_mean(buf));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        for r in 0..n {
            assert_eq!(got[r], refbufs[r], "rank {r}");
        }
    }

    #[test]
    fn striped_allreduce_bitwise_across_edge_lengths() {
        // Lengths around the stripe boundaries: shorter than the group
        // (empty tail stripes), exactly divisible, off-by-one, and a
        // value-pattern where f32 addition order matters.
        for n in [2usize, 3, 4, 8] {
            for len in [0usize, 1, n - 1, n, n + 1, 37, 1 << 10] {
                let got = run_threads(n, len, |c, buf| c.all_reduce_mean(buf));
                let mut refbufs: Vec<Vec<f32>> = (0..n)
                    .map(|r| (0..len).map(|i| (r * len + i) as f32).collect())
                    .collect();
                let mut refs: Vec<&mut [f32]> =
                    refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                group::all_reduce_mean(&mut refs);
                for r in 0..n {
                    assert_eq!(got[r], refbufs[r], "n={n} len={len} rank {r}");
                }
            }
        }
    }

    #[test]
    fn striped_allreduce_order_sensitive_values_bitwise() {
        // Magnitude-staggered values make f32 addition order observable:
        // any deviation from the rank-0..n fold changes the result.
        let n = 4;
        let len = 23;
        let comms = ThreadComm::group(n);
        let make = |r: usize| -> Vec<f32> {
            (0..len)
                .map(|i| {
                    let m = [1e8f32, 1.0, -1e8, 3.0][r];
                    m + (i as f32) * 0.125
                })
                .collect()
        };
        let mut got = vec![Vec::new(); n];
        let make = &make;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    s.spawn(move || {
                        let mut buf = make(c.rank());
                        c.all_reduce_mean(&mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                got[r] = h.join().unwrap();
            }
        });
        let mut refbufs: Vec<Vec<f32>> = (0..n).map(make).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_allgather_matches_sequential() {
        let n = 3;
        let len = 10;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let sh = shards.clone();
        let got = run_threads(n, len, move |c, buf| c.all_gather(buf, &sh));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_gather(&mut refs, &shards);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_reduce_scatter_matches_sequential() {
        for (n, len) in [(4usize, 16usize), (3, 7), (8, 8), (2, 1)] {
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let sh = shards.clone();
            let got = run_threads(n, len, move |c, buf| c.reduce_scatter_mean(buf, &sh));
            let mut refbufs: Vec<Vec<f32>> =
                (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_mean(&mut refs, &shards);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_reduce_scatter_sum_matches_sequential() {
        for (n, len) in [(4usize, 16usize), (3, 7), (2, 1)] {
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let sh = shards.clone();
            let got = run_threads(n, len, move |c, buf| c.reduce_scatter_sum(buf, &sh));
            let mut refbufs: Vec<Vec<f32>> =
                (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_sum(&mut refs, &shards);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_reduce_scatter_weighted_matches_sequential() {
        // Magnitude-staggered values + a zero weight: any deviation from
        // the ascending-rank skip-zero fold changes the f32 result.
        for (n, len) in [(4usize, 23usize), (3, 5), (1, 4)] {
            let weights: Vec<f32> =
                (0..n).map(|r| if r == 1 { 0.0 } else { 0.3 + r as f32 * 0.21 }).collect();
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let make = |r: usize| -> Vec<f32> {
                (0..len)
                    .map(|i| [1e7f32, 3.0, -1e7, 5.0][r % 4] + (i as f32) * 0.125)
                    .collect()
            };
            let comms = ThreadComm::group(n);
            let mut got = vec![Vec::new(); n];
            let (sh, ws, mk) = (&shards, &weights, &make);
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let mut buf = mk(c.rank());
                            c.reduce_scatter_weighted(&mut buf, sh, ws);
                            buf
                        })
                    })
                    .collect();
                for (r, h) in handles.into_iter().enumerate() {
                    got[r] = h.join().unwrap();
                }
            });
            let mut refbufs: Vec<Vec<f32>> = (0..n).map(mk).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_weighted(&mut refs, &shards, &weights);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_broadcast() {
        let got = run_threads(3, 5, |c, buf| c.broadcast(buf, 2));
        let expect: Vec<f32> = (0..5).map(|i| (2 * 5 + i) as f32).collect();
        for b in &got {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn repeated_ops_no_deadlock() {
        let got = run_threads(4, 8, |c, buf| {
            for _ in 0..25 {
                c.all_reduce_mean(buf);
                c.barrier();
                c.broadcast(buf, 1);
            }
        });
        for b in &got[1..] {
            assert_eq!(b, &got[0]);
        }
    }
}
