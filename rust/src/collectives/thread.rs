//! Rendezvous-based threaded communicator.
//!
//! The real-concurrency counterpart of [`super::group`]: N OS threads
//! (one per simulated worker) meet at a staging area + barrier, exactly
//! like an NCCL communicator.  Reduction order is the same deterministic
//! rank-0..n fold as the sequential reference, and the test suite
//! asserts bitwise equality between both implementations.
//!
//! The numerics trainer runs single-threaded (PJRT client is not Send,
//! and this box has one core), so this module is exercised by tests,
//! benches, and any future multi-process deployment of the coordinator.

use std::sync::{Arc, Barrier, Mutex};

struct Inner {
    n: usize,
    staging: Mutex<Vec<Vec<f32>>>,
    barrier: Barrier,
}

/// Per-rank handle; clone-free — create one set via [`ThreadComm::group`].
pub struct ThreadComm {
    rank: usize,
    inner: Arc<Inner>,
}

impl ThreadComm {
    /// Create handles for an `n`-rank group.
    pub fn group(n: usize) -> Vec<ThreadComm> {
        let inner = Arc::new(Inner {
            n,
            staging: Mutex::new(vec![Vec::new(); n]),
            barrier: Barrier::new(n),
        });
        (0..n).map(|rank| ThreadComm { rank, inner: Arc::clone(&inner) }).collect()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.inner.n
    }

    pub fn barrier(&self) {
        self.inner.barrier.wait();
    }

    fn stage(&self, data: &[f32]) {
        let mut staging = self.inner.staging.lock().unwrap();
        let slot = &mut staging[self.rank];
        slot.clear();
        slot.extend_from_slice(data);
    }

    /// Mean all-reduce across the group (every rank ends with the mean).
    pub fn all_reduce_mean(&self, buf: &mut [f32]) {
        if self.inner.n == 1 {
            return;
        }
        self.stage(buf);
        self.inner.barrier.wait();
        {
            // Every rank folds in the same 0..n order => deterministic and
            // identical across ranks.
            let staging = self.inner.staging.lock().unwrap();
            buf.copy_from_slice(&staging[0]);
            for r in 1..self.inner.n {
                for (acc, &x) in buf.iter_mut().zip(&staging[r]) {
                    *acc += x;
                }
            }
        }
        let inv = 1.0 / self.inner.n as f32;
        for x in buf.iter_mut() {
            *x *= inv;
        }
        // Second barrier: nobody restages until all have read.
        self.inner.barrier.wait();
    }

    /// All-gather: each rank contributes `full[shards[rank]]`; on return
    /// `full` holds every shard. `shards[r] = (offset, len)`.
    pub fn all_gather(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        if self.inner.n == 1 {
            return;
        }
        let (off, len) = shards[self.rank];
        self.stage(&full[off..off + len]);
        self.inner.barrier.wait();
        {
            let staging = self.inner.staging.lock().unwrap();
            for (r, &(o, l)) in shards.iter().enumerate() {
                if r != self.rank {
                    full[o..o + l].copy_from_slice(&staging[r]);
                }
            }
        }
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (mean): on return this rank's shard region holds the
    /// group mean of that region; the rest of `full` is untouched.
    pub fn reduce_scatter_mean(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        if self.inner.n == 1 {
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        {
            let staging = self.inner.staging.lock().unwrap();
            let inv = 1.0 / self.inner.n as f32;
            for i in 0..len {
                let mut acc = 0.0f32;
                for r in 0..self.inner.n {
                    acc += staging[r][off + i];
                }
                full[off + i] = acc * inv;
            }
        }
        self.inner.barrier.wait();
    }

    /// Broadcast `root`'s buffer to every rank.
    pub fn broadcast(&self, buf: &mut [f32], root: usize) {
        if self.inner.n == 1 {
            return;
        }
        if self.rank == root {
            self.stage(buf);
        }
        self.inner.barrier.wait();
        if self.rank != root {
            let staging = self.inner.staging.lock().unwrap();
            buf.copy_from_slice(&staging[root]);
        }
        self.inner.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::group;
    use crate::tensor::ShardSpec;

    fn run_threads<F>(n: usize, len: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&ThreadComm, &mut Vec<f32>) + Send + Sync,
    {
        let comms = ThreadComm::group(n);
        let mut out = vec![Vec::new(); n];
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    s.spawn(move || {
                        let mut buf: Vec<f32> =
                            (0..len).map(|i| (comm.rank() * len + i) as f32).collect();
                        f(&comm, &mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                out[r] = h.join().unwrap();
            }
        });
        out
    }

    #[test]
    fn threaded_allreduce_matches_sequential() {
        let n = 4;
        let len = 37;
        let got = run_threads(n, len, |c, buf| c.all_reduce_mean(buf));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        for r in 0..n {
            assert_eq!(got[r], refbufs[r], "rank {r}");
        }
    }

    #[test]
    fn threaded_allgather_matches_sequential() {
        let n = 3;
        let len = 10;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let sh = shards.clone();
        let got = run_threads(n, len, move |c, buf| c.all_gather(buf, &sh));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_gather(&mut refs, &shards);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_reduce_scatter_matches_sequential() {
        let n = 4;
        let len = 16;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let sh = shards.clone();
        let got = run_threads(n, len, move |c, buf| c.reduce_scatter_mean(buf, &sh));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::reduce_scatter_mean(&mut refs, &shards);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_broadcast() {
        let got = run_threads(3, 5, |c, buf| c.broadcast(buf, 2));
        let expect: Vec<f32> = (0..5).map(|i| (2 * 5 + i) as f32).collect();
        for b in &got {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn repeated_ops_no_deadlock() {
        let got = run_threads(4, 8, |c, buf| {
            for _ in 0..25 {
                c.all_reduce_mean(buf);
                c.barrier();
                c.broadcast(buf, 1);
            }
        });
        for b in &got[1..] {
            assert_eq!(b, &got[0]);
        }
    }
}
