//! Rendezvous-based threaded communicator.
//!
//! The real-concurrency counterpart of [`super::group`]: N OS threads
//! (one per simulated worker) meet at a staging area + barrier, exactly
//! like an NCCL communicator.  Reduction order is the same deterministic
//! rank-0..n fold as the sequential reference, and the test suite
//! asserts bitwise equality between both implementations.
//!
//! Concurrency layout (the striped rework): contributions live in
//! per-rank `RwLock` slots, so staging takes one short uncontended write
//! lock and the reduce phase reads every slot *in parallel* instead of
//! serializing all ranks behind a single staging mutex.  For the
//! all-reduce, each rank folds only its own contiguous stripe
//! (`ShardSpec` split) into a shared stripe slab and then gathers every
//! stripe — ring-style bandwidth parallelism with the sequential fold
//! order preserved per element, so results stay bitwise equal to
//! [`super::group::all_reduce_mean`].
//!
//! Steady-state allocation: every slot (staging and stripe) is a `Vec`
//! that is `clear()`ed and refilled, so repeated collectives reuse their
//! capacity and allocate nothing after the first round at a given size.
//!
//! The numerics trainer runs single-threaded (PJRT client is not Send,
//! and this box has one core), so this module is exercised by tests,
//! benches, and any future multi-process deployment of the coordinator.

//! # Fallible surface ([`crate::collectives::Collective`])
//!
//! The infallible ops above assume every rank always arrives — a dead
//! peer deadlocks the `Barrier`. The `try_*` ops replace it with a
//! condvar **rendezvous gate** that counts only live ranks: marking a
//! rank failed ([`ThreadComm::mark_failed`]) wakes current waiters so
//! they re-count the quorum, and later ops simply rendezvous without
//! the dead rank. Degraded reductions fold the live ranks in ascending
//! rank order over the full vector (means divide by the live count) —
//! the same membership semantics the trainer's sync paths apply when a
//! replica crashes, favoring simplicity over the striped fast path
//! (fault handling is not the hot path).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Barrier, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::collectives::{group, Collective, CommError, CommHandle, CommResult, PIPELINE_WINDOW};
use crate::tensor::{kernels, ShardSpec, QUANT_CHUNK};

/// Generation-counted rendezvous state (sense-reversing: waiters key on
/// the generation, so back-to-back rendezvous cannot mix arrivals).
struct Gate {
    arrived: usize,
    generation: u64,
}

/// Per-rank staging slot for the int8 payload lane: the codes + scales
/// that would travel the wire under `payload=int8`. Buffers are cleared
/// and refilled, so repeated quantized collectives at a size allocate
/// nothing after the first round.
#[derive(Default)]
struct QSlot {
    codes: Vec<i8>,
    scales: Vec<f32>,
}

struct Inner {
    n: usize,
    /// Per-rank contribution slots.
    staging: Vec<RwLock<Vec<f32>>>,
    /// Per-rank reduced-stripe slots (all-reduce slab).
    stripes: Vec<RwLock<Vec<f32>>>,
    /// Per-rank quantized-payload slots (int8 reduce-scatter lane).
    qslots: Vec<RwLock<QSlot>>,
    barrier: Barrier,
    /// Liveness flags for the fallible surface (true = failed).
    failed: Vec<AtomicBool>,
    shutdown: AtomicBool,
    gate: Mutex<Gate>,
    cv: Condvar,
    /// Modeled per-op wire latency (zero by default): every fallible
    /// data op sleeps this long at issue before staging. With the
    /// blocking surface the sleep lands on the caller; with the
    /// nonblocking surface it lands on the comm worker, where it
    /// overlaps caller compute — the latency-hiding the overlap benches
    /// measure (this box has one core, so the win must come from
    /// hiding waits, not parallel arithmetic).
    link_delay: Duration,
}

/// A nonblocking op queued to the comm worker. Buffers travel by value;
/// the result goes back on the per-op reply channel (dropping the
/// receiver — a dropped [`CommHandle`] — just discards the result; the
/// op itself still completes, keeping rendezvous state consistent).
enum Job {
    AllReduceMean {
        buf: Vec<f32>,
        timeout: Duration,
        reply: mpsc::Sender<CommResult<Vec<f32>>>,
    },
    ReduceScatterMean {
        full: Vec<f32>,
        shards: Vec<(usize, usize)>,
        timeout: Duration,
        reply: mpsc::Sender<CommResult<Vec<f32>>>,
    },
    ReduceScatterMeanQ8 {
        full: Vec<f32>,
        shards: Vec<(usize, usize)>,
        timeout: Duration,
        reply: mpsc::Sender<CommResult<Vec<f32>>>,
    },
    ReduceScatterWeighted {
        full: Vec<f32>,
        shards: Vec<(usize, usize)>,
        weights: Vec<f32>,
        timeout: Duration,
        reply: mpsc::Sender<CommResult<Vec<f32>>>,
    },
    AllGather {
        full: Vec<f32>,
        shards: Vec<(usize, usize)>,
        timeout: Duration,
        reply: mpsc::Sender<CommResult<Vec<f32>>>,
    },
    /// Rendezvous-free sync point: the worker replies once every job
    /// queued before this one has completed.
    Flush { reply: mpsc::Sender<()> },
}

/// Lazily spawned comm worker executing this rank's `start_*` ops in
/// issue order on a dedicated thread.
struct Worker {
    tx: mpsc::SyncSender<Job>,
    join: std::thread::JoinHandle<()>,
    /// Jobs enqueued since the last flush — lets blocking ops skip the
    /// flush round-trip when the worker is idle.
    dirty: bool,
}

/// Per-rank handle; clone-free — create one set via [`ThreadComm::group`].
pub struct ThreadComm {
    rank: usize,
    inner: Arc<Inner>,
    /// This rank's comm worker (nonblocking surface); `None` until the
    /// first `start_*` op, and always `None` on the worker's own
    /// duplicate handle (the worker runs the blocking impls directly).
    worker: Mutex<Option<Worker>>,
}

impl ThreadComm {
    /// Create handles for an `n`-rank group.
    pub fn group(n: usize) -> Vec<ThreadComm> {
        Self::group_with_link_delay(n, Duration::ZERO)
    }

    /// [`Self::group`] with a modeled per-op wire latency: every
    /// fallible data op (not the barrier) sleeps `link_delay` at issue.
    /// Bench substrate for overlap measurements — the sleep stands in
    /// for time on the wire, which the nonblocking surface can hide
    /// behind caller compute and the blocking surface cannot.
    pub fn group_with_link_delay(n: usize, link_delay: Duration) -> Vec<ThreadComm> {
        let inner = Arc::new(Inner {
            n,
            staging: (0..n).map(|_| RwLock::new(Vec::new())).collect(),
            stripes: (0..n).map(|_| RwLock::new(Vec::new())).collect(),
            qslots: (0..n).map(|_| RwLock::new(QSlot::default())).collect(),
            barrier: Barrier::new(n),
            failed: (0..n).map(|_| AtomicBool::new(false)).collect(),
            shutdown: AtomicBool::new(false),
            gate: Mutex::new(Gate { arrived: 0, generation: 0 }),
            cv: Condvar::new(),
            link_delay,
        });
        (0..n)
            .map(|rank| ThreadComm { rank, inner: Arc::clone(&inner), worker: Mutex::new(None) })
            .collect()
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.inner.n
    }

    pub fn barrier(&self) {
        self.inner.barrier.wait();
    }

    fn stage(&self, data: &[f32]) {
        let mut slot = self.inner.staging[self.rank].write().unwrap();
        slot.clear();
        slot.extend_from_slice(data);
    }

    /// Mean all-reduce across the group (every rank ends with the mean).
    ///
    /// Striped: rank r sums ranks' contributions over stripe r only
    /// (fold order 0..n, then the 1/n scale — per element exactly the
    /// sequential reference's operation sequence), publishes the stripe,
    /// and gathers the other stripes after the barrier.
    pub fn all_reduce_mean(&self, buf: &mut [f32]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(buf);
        self.inner.barrier.wait();

        let spec = ShardSpec::new(buf.len(), n);
        let inv = 1.0 / n as f32;
        {
            let (off, len) = spec.range(self.rank);
            let mut stripe = self.inner.stripes[self.rank].write().unwrap();
            stripe.clear();
            {
                let s0 = self.inner.staging[0].read().unwrap();
                stripe.extend_from_slice(&s0[off..off + len]);
            }
            for r in 1..n {
                let sr = self.inner.staging[r].read().unwrap();
                kernels::add(&mut stripe[..], &sr[off..off + len]);
            }
            kernels::scale(&mut stripe[..], inv);
        }
        // All stripes reduced before anyone gathers.
        self.inner.barrier.wait();
        for r in 0..n {
            let (off, len) = spec.range(r);
            let sr = self.inner.stripes[r].read().unwrap();
            buf[off..off + len].copy_from_slice(&sr);
        }
        // Nobody restages (or re-reduces into a stripe) until all have read.
        self.inner.barrier.wait();
    }

    /// All-gather: each rank contributes `full[shards[rank]]`; on return
    /// `full` holds every shard. `shards[r] = (offset, len)`.
    pub fn all_gather(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        if self.inner.n == 1 {
            return;
        }
        let (off, len) = shards[self.rank];
        self.stage(&full[off..off + len]);
        self.inner.barrier.wait();
        for (r, &(o, l)) in shards.iter().enumerate() {
            if r != self.rank {
                let sr = self.inner.staging[r].read().unwrap();
                full[o..o + l].copy_from_slice(&sr);
            }
        }
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (mean): on return this rank's shard region holds the
    /// group mean of that region; the rest of `full` is untouched.  Each
    /// rank folds only its own shard, reading the per-rank slots in
    /// parallel (fold order 0..n preserved).
    pub fn reduce_scatter_mean(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        {
            let s0 = self.inner.staging[0].read().unwrap();
            full[off..off + len].copy_from_slice(&s0[off..off + len]);
        }
        for r in 1..n {
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        kernels::scale(&mut full[off..off + len], 1.0 / n as f32);
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (sum): like [`Self::reduce_scatter_mean`] without
    /// the 1/n scale — rank-0..n fold order, bitwise equal to
    /// [`super::group::reduce_scatter_sum`]. The fold starts from a
    /// zero-initialized accumulator and adds every rank including rank
    /// 0, exactly like the reference (seeding by copying rank 0's shard
    /// would diverge bitwise on -0.0 inputs).
    pub fn reduce_scatter_sum(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..n {
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        self.inner.barrier.wait();
    }

    /// Reduce-scatter (mean) over int8-quantized wire payloads: each
    /// rank stages codes + per-[`QUANT_CHUNK`] scales (the bytes that
    /// would travel the wire under `payload=int8`,
    /// [`group::quantize_int8_into`]), and this rank's shard ends with
    /// the mean of the **dequantized** contributions — ascending-rank
    /// fold per element, then the 1/n scale, bitwise equal to
    /// [`group::reduce_scatter_mean_q8`]. The quantization error stays
    /// with the sender (error feedback is the caller's job).
    pub fn reduce_scatter_mean_q8(&self, full: &mut [f32], shards: &[(usize, usize)]) {
        let n = self.inner.n;
        if n == 1 {
            return;
        }
        {
            let mut slot = self.inner.qslots[self.rank].write().unwrap();
            let QSlot { codes, scales } = &mut *slot;
            group::quantize_int8_into(full, codes, scales);
        }
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..n {
            let sr = self.inner.qslots[r].read().unwrap();
            for i in off..off + len {
                full[i] += sr.codes[i] as f32 * sr.scales[i / QUANT_CHUNK];
            }
        }
        kernels::scale(&mut full[off..off + len], 1.0 / n as f32);
        self.inner.barrier.wait();
    }

    /// Weighted reduce-scatter: this rank's shard ends with
    /// `Σ_j weights[j]·x_j` over its region (ascending-rank fold,
    /// zero-weight ranks skipped — bitwise equal to
    /// [`super::group::reduce_scatter_weighted`]).
    pub fn reduce_scatter_weighted(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
    ) {
        let n = self.inner.n;
        debug_assert_eq!(n, weights.len());
        if n == 1 {
            // Degenerate group: reproduce the reference's zero-init +
            // single-fold accumulation exactly (incl. the -0.0 edge).
            let (off, len) = shards[self.rank];
            let w = weights[0];
            for x in full[off..off + len].iter_mut() {
                let mut acc = 0.0f32;
                if w != 0.0 {
                    acc += w * *x;
                }
                *x = acc;
            }
            return;
        }
        self.stage(full);
        self.inner.barrier.wait();
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for (r, &w) in weights.iter().enumerate() {
            if w != 0.0 {
                let sr = self.inner.staging[r].read().unwrap();
                kernels::axpy(&mut full[off..off + len], w, &sr[off..off + len]);
            }
        }
        self.inner.barrier.wait();
    }

    /// Broadcast `root`'s buffer to every rank.
    pub fn broadcast(&self, buf: &mut [f32], root: usize) {
        if self.inner.n == 1 {
            return;
        }
        if self.rank == root {
            self.stage(buf);
        }
        self.inner.barrier.wait();
        if self.rank != root {
            let slot = self.inner.staging[root].read().unwrap();
            buf.copy_from_slice(&slot);
        }
        self.inner.barrier.wait();
    }

    // --- fallible surface (see module docs / `collectives::Collective`) ---

    /// Mark `rank` failed: it no longer counts toward any rendezvous
    /// quorum, and reductions skip its contribution. Wakes current
    /// waiters so a rendezvous blocked on the dead rank re-counts and
    /// completes. Any live rank (or an external monitor holding a
    /// handle) may report a failure.
    pub fn mark_failed(&self, rank: usize) {
        self.inner.failed[rank].store(true, Ordering::SeqCst);
        let _g = self.inner.gate.lock().unwrap();
        self.inner.cv.notify_all();
    }

    /// Whether `rank` is marked failed.
    pub fn is_failed(&self, rank: usize) -> bool {
        self.inner.failed[rank].load(Ordering::SeqCst)
    }

    /// Ranks still participating in rendezvous.
    pub fn live_ranks(&self) -> usize {
        self.inner
            .failed
            .iter()
            .filter(|f| !f.load(Ordering::SeqCst))
            .count()
    }

    /// Tear the communicator down: every current and future `try_*` op
    /// returns [`CommError::Shutdown`].
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        let _g = self.inner.gate.lock().unwrap();
        self.inner.cv.notify_all();
    }

    /// Rendezvous with every live rank, or time out. The arrival is
    /// undone on timeout so a later retry starts from a clean count
    /// (the rendezvous-level mirror of `RetryPolicy`'s attempts).
    fn try_rendezvous(&self, op: &'static str, timeout: Duration) -> CommResult<()> {
        let inner = &self.inner;
        if inner.shutdown.load(Ordering::SeqCst) {
            return Err(CommError::Shutdown);
        }
        let deadline = Instant::now() + timeout;
        let mut g = inner.gate.lock().unwrap();
        g.arrived += 1;
        if g.arrived >= self.live_ranks() {
            g.arrived = 0;
            g.generation = g.generation.wrapping_add(1);
            inner.cv.notify_all();
            return Ok(());
        }
        let gen = g.generation;
        loop {
            if inner.shutdown.load(Ordering::SeqCst) {
                g.arrived = g.arrived.saturating_sub(1);
                inner.cv.notify_all();
                return Err(CommError::Shutdown);
            }
            if g.generation != gen {
                // A peer completed the rendezvous (and consumed our
                // arrival) while we waited.
                return Ok(());
            }
            // A peer may have been marked failed while we waited —
            // re-count the quorum before sleeping again.
            if g.arrived >= self.live_ranks() {
                g.arrived = 0;
                g.generation = g.generation.wrapping_add(1);
                inner.cv.notify_all();
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                g.arrived = g.arrived.saturating_sub(1);
                return Err(CommError::Timeout { op, waited: timeout });
            }
            let (guard, _) = inner.cv.wait_timeout(g, deadline - now).unwrap();
            g = guard;
        }
    }

    fn try_barrier_impl(&self, timeout: Duration) -> CommResult<()> {
        if self.live_ranks() <= 1 {
            return check_shutdown(&self.inner);
        }
        self.try_rendezvous("barrier", timeout)
    }

    fn try_all_reduce_mean_impl(&self, buf: &mut [f32], timeout: Duration) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        if self.live_ranks() <= 1 {
            // Sole survivor: the live-group mean is its own contribution.
            return Ok(());
        }
        self.stage(buf);
        self.try_rendezvous("all_reduce_mean", timeout)?;
        let inv = 1.0 / self.live_ranks() as f32;
        buf.fill(0.0);
        for r in 0..self.inner.n {
            if self.is_failed(r) {
                continue;
            }
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(buf, &sr[..]);
        }
        kernels::scale(buf, inv);
        // Nobody restages until every live rank has read.
        self.try_rendezvous("all_reduce_mean.exit", timeout)
    }

    fn try_all_gather_impl(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        // Every shard owner must be alive — a dead rank's shard cannot
        // be reconstructed by the survivors. Deterministic failure.
        for (r, &(_, len)) in shards.iter().enumerate() {
            if len > 0 && self.is_failed(r) {
                return Err(CommError::PeerFailed { rank: r });
            }
        }
        if self.live_ranks() <= 1 {
            return Ok(());
        }
        let (off, len) = shards[self.rank];
        self.stage(&full[off..off + len]);
        self.try_rendezvous("all_gather", timeout)?;
        for (r, &(o, l)) in shards.iter().enumerate() {
            if r != self.rank && !self.is_failed(r) {
                let sr = self.inner.staging[r].read().unwrap();
                full[o..o + l].copy_from_slice(&sr);
            }
        }
        self.try_rendezvous("all_gather.exit", timeout)
    }

    fn try_reduce_scatter_mean_impl(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        if self.live_ranks() <= 1 {
            return Ok(());
        }
        self.stage(full);
        self.try_rendezvous("reduce_scatter_mean", timeout)?;
        let inv = 1.0 / self.live_ranks() as f32;
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..self.inner.n {
            if self.is_failed(r) {
                continue;
            }
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        kernels::scale(&mut full[off..off + len], inv);
        self.try_rendezvous("reduce_scatter_mean.exit", timeout)
    }

    fn try_reduce_scatter_sum_impl(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        if self.live_ranks() <= 1 {
            // Sole survivor: the live-group sum is its own contribution.
            return Ok(());
        }
        self.stage(full);
        self.try_rendezvous("reduce_scatter_sum", timeout)?;
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..self.inner.n {
            if self.is_failed(r) {
                continue;
            }
            let sr = self.inner.staging[r].read().unwrap();
            kernels::add(&mut full[off..off + len], &sr[off..off + len]);
        }
        self.try_rendezvous("reduce_scatter_sum.exit", timeout)
    }

    fn try_reduce_scatter_weighted_impl(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        debug_assert_eq!(self.inner.n, weights.len());
        if self.live_ranks() <= 1 {
            // Unlike sum/mean, w·x is a real computation even alone:
            // reproduce the reference's zero-init single fold.
            let (off, len) = shards[self.rank];
            let w = weights[self.rank];
            for x in full[off..off + len].iter_mut() {
                let mut acc = 0.0f32;
                if w != 0.0 {
                    acc += w * *x;
                }
                *x = acc;
            }
            return Ok(());
        }
        self.stage(full);
        self.try_rendezvous("reduce_scatter_weighted", timeout)?;
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for (r, &w) in weights.iter().enumerate() {
            if w != 0.0 && !self.is_failed(r) {
                let sr = self.inner.staging[r].read().unwrap();
                kernels::axpy(&mut full[off..off + len], w, &sr[off..off + len]);
            }
        }
        self.try_rendezvous("reduce_scatter_weighted.exit", timeout)
    }

    fn try_reduce_scatter_mean_q8_impl(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        if self.live_ranks() <= 1 {
            return Ok(());
        }
        {
            let mut slot = self.inner.qslots[self.rank].write().unwrap();
            let QSlot { codes, scales } = &mut *slot;
            group::quantize_int8_into(full, codes, scales);
        }
        self.try_rendezvous("reduce_scatter_mean_q8", timeout)?;
        let inv = 1.0 / self.live_ranks() as f32;
        let (off, len) = shards[self.rank];
        full[off..off + len].fill(0.0);
        for r in 0..self.inner.n {
            if self.is_failed(r) {
                continue;
            }
            let sr = self.inner.qslots[r].read().unwrap();
            for i in off..off + len {
                full[i] += sr.codes[i] as f32 * sr.scales[i / QUANT_CHUNK];
            }
        }
        kernels::scale(&mut full[off..off + len], inv);
        self.try_rendezvous("reduce_scatter_mean_q8.exit", timeout)
    }

    fn try_broadcast_impl(
        &self,
        buf: &mut [f32],
        root: usize,
        timeout: Duration,
    ) -> CommResult<()> {
        check_shutdown(&self.inner)?;
        self.sleep_link_delay();
        if self.is_failed(root) {
            // The payload only exists on the root. Deterministic failure.
            return Err(CommError::PeerFailed { rank: root });
        }
        if self.live_ranks() <= 1 {
            return Ok(());
        }
        if self.rank == root {
            self.stage(buf);
        }
        self.try_rendezvous("broadcast", timeout)?;
        if self.rank != root {
            let slot = self.inner.staging[root].read().unwrap();
            buf.copy_from_slice(&slot);
        }
        self.try_rendezvous("broadcast.exit", timeout)
    }

    // --- nonblocking surface (comm worker) --------------------------------

    /// Model the wire: sleep `link_delay` at op issue (no-op by default).
    fn sleep_link_delay(&self) {
        if !self.inner.link_delay.is_zero() {
            std::thread::sleep(self.inner.link_delay);
        }
    }

    /// Hand out the comm-worker job queue, spawning the worker on first
    /// use. The worker holds a duplicate handle at this rank (same
    /// `Inner`, no worker of its own) and executes the blocking impls
    /// in issue order, so nonblocking ops are sequenced exactly like a
    /// caller that waited — only on another thread.
    fn worker_tx(&self) -> mpsc::SyncSender<Job> {
        let mut guard = self.worker.lock().unwrap();
        if guard.is_none() {
            let (tx, rx) = mpsc::sync_channel::<Job>(PIPELINE_WINDOW);
            let peer = ThreadComm {
                rank: self.rank,
                inner: Arc::clone(&self.inner),
                worker: Mutex::new(None),
            };
            let join = std::thread::spawn(move || worker_loop(peer, rx));
            *guard = Some(Worker { tx, join, dirty: false });
        }
        let worker = guard.as_mut().unwrap();
        worker.dirty = true;
        worker.tx.clone()
    }

    /// Drain the comm worker before a blocking op: two threads of the
    /// same rank must never rendezvous concurrently (the gate counts
    /// arrivals per rank-agnostic quorum, so a blocking op racing the
    /// worker's queued op would corrupt the count). Skipped when the
    /// worker is idle or was never spawned.
    fn flush_worker(&self) {
        let tx = {
            let mut guard = self.worker.lock().unwrap();
            match guard.as_mut() {
                Some(worker) if worker.dirty => {
                    worker.dirty = false;
                    worker.tx.clone()
                }
                _ => return,
            }
        };
        let (reply, rx) = mpsc::channel();
        if tx.send(Job::Flush { reply }).is_ok() {
            let _ = rx.recv();
        }
    }

    fn issue(&self, job: Job) -> Option<CommHandle> {
        // Reply channel is embedded in `job`; a send failure means the
        // worker died (shutdown) — surface that through the handle.
        match self.worker_tx().send(job) {
            Ok(()) => None,
            Err(_) => Some(CommHandle::ready(Err(CommError::Shutdown))),
        }
    }
}

/// Comm-worker main loop: execute jobs in issue order; a failed reply
/// send (dropped [`CommHandle`]) discards the result but never the op.
fn worker_loop(comm: ThreadComm, rx: mpsc::Receiver<Job>) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::AllReduceMean { mut buf, timeout, reply } => {
                let r = comm.try_all_reduce_mean_impl(&mut buf, timeout).map(|()| buf);
                let _ = reply.send(r);
            }
            Job::ReduceScatterMean { mut full, shards, timeout, reply } => {
                let r =
                    comm.try_reduce_scatter_mean_impl(&mut full, &shards, timeout).map(|()| full);
                let _ = reply.send(r);
            }
            Job::ReduceScatterMeanQ8 { mut full, shards, timeout, reply } => {
                let r = comm
                    .try_reduce_scatter_mean_q8_impl(&mut full, &shards, timeout)
                    .map(|()| full);
                let _ = reply.send(r);
            }
            Job::ReduceScatterWeighted { mut full, shards, weights, timeout, reply } => {
                let r = comm
                    .try_reduce_scatter_weighted_impl(&mut full, &shards, &weights, timeout)
                    .map(|()| full);
                let _ = reply.send(r);
            }
            Job::AllGather { mut full, shards, timeout, reply } => {
                let r = comm.try_all_gather_impl(&mut full, &shards, timeout).map(|()| full);
                let _ = reply.send(r);
            }
            Job::Flush { reply } => {
                let _ = reply.send(());
            }
        }
    }
}

impl Drop for ThreadComm {
    fn drop(&mut self) {
        // Disconnect the job queue and join the worker; queued ops run
        // to completion first (bounded by their own timeouts), so no
        // peer is left waiting on a rendezvous this rank had entered.
        if let Some(worker) = self.worker.get_mut().unwrap().take() {
            drop(worker.tx);
            let _ = worker.join.join();
        }
    }
}

fn check_shutdown(inner: &Inner) -> CommResult<()> {
    if inner.shutdown.load(Ordering::SeqCst) {
        Err(CommError::Shutdown)
    } else {
        Ok(())
    }
}

impl Collective for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.inner.n
    }

    // Blocking ops flush the comm worker first: a rank must never have
    // two threads inside the rendezvous gate at once.

    fn try_barrier(&self, timeout: Duration) -> CommResult<()> {
        self.flush_worker();
        self.try_barrier_impl(timeout)
    }

    fn try_all_reduce_mean(&self, buf: &mut [f32], timeout: Duration) -> CommResult<()> {
        self.flush_worker();
        self.try_all_reduce_mean_impl(buf, timeout)
    }

    fn try_all_gather(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.flush_worker();
        self.try_all_gather_impl(full, shards, timeout)
    }

    fn try_reduce_scatter_mean(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.flush_worker();
        self.try_reduce_scatter_mean_impl(full, shards, timeout)
    }

    fn try_reduce_scatter_sum(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.flush_worker();
        self.try_reduce_scatter_sum_impl(full, shards, timeout)
    }

    fn try_reduce_scatter_weighted(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommResult<()> {
        self.flush_worker();
        self.try_reduce_scatter_weighted_impl(full, shards, weights, timeout)
    }

    fn try_reduce_scatter_mean_q8(
        &self,
        full: &mut [f32],
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommResult<()> {
        self.flush_worker();
        self.try_reduce_scatter_mean_q8_impl(full, shards, timeout)
    }

    fn try_broadcast(&self, buf: &mut [f32], root: usize, timeout: Duration) -> CommResult<()> {
        self.flush_worker();
        self.try_broadcast_impl(buf, root, timeout)
    }

    // Nonblocking ops queue to the comm worker and return immediately;
    // results are bitwise what the blocking op would have produced,
    // because the worker runs the very same impls in issue order.

    fn start_all_reduce_mean(&self, buf: Vec<f32>, timeout: Duration) -> CommHandle {
        let (reply, rx) = mpsc::channel();
        match self.issue(Job::AllReduceMean { buf, timeout, reply }) {
            Some(failed) => failed,
            None => CommHandle::thread(rx),
        }
    }

    fn start_reduce_scatter_mean(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let (reply, rx) = mpsc::channel();
        let job = Job::ReduceScatterMean { full, shards: shards.to_vec(), timeout, reply };
        match self.issue(job) {
            Some(failed) => failed,
            None => CommHandle::thread(rx),
        }
    }

    fn start_reduce_scatter_mean_q8(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let (reply, rx) = mpsc::channel();
        let job = Job::ReduceScatterMeanQ8 { full, shards: shards.to_vec(), timeout, reply };
        match self.issue(job) {
            Some(failed) => failed,
            None => CommHandle::thread(rx),
        }
    }

    fn start_reduce_scatter_weighted(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        weights: &[f32],
        timeout: Duration,
    ) -> CommHandle {
        let (reply, rx) = mpsc::channel();
        let job = Job::ReduceScatterWeighted {
            full,
            shards: shards.to_vec(),
            weights: weights.to_vec(),
            timeout,
            reply,
        };
        match self.issue(job) {
            Some(failed) => failed,
            None => CommHandle::thread(rx),
        }
    }

    fn start_all_gather(
        &self,
        full: Vec<f32>,
        shards: &[(usize, usize)],
        timeout: Duration,
    ) -> CommHandle {
        let (reply, rx) = mpsc::channel();
        let job = Job::AllGather { full, shards: shards.to_vec(), timeout, reply };
        match self.issue(job) {
            Some(failed) => failed,
            None => CommHandle::thread(rx),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::group;
    use crate::tensor::ShardSpec;

    fn run_threads<F>(n: usize, len: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&ThreadComm, &mut Vec<f32>) + Send + Sync,
    {
        let comms = ThreadComm::group(n);
        let mut out = vec![Vec::new(); n];
        let f = &f;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|comm| {
                    s.spawn(move || {
                        let mut buf: Vec<f32> =
                            (0..len).map(|i| (comm.rank() * len + i) as f32).collect();
                        f(&comm, &mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                out[r] = h.join().unwrap();
            }
        });
        out
    }

    #[test]
    fn threaded_allreduce_matches_sequential() {
        let n = 4;
        let len = 37;
        let got = run_threads(n, len, |c, buf| c.all_reduce_mean(buf));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        for r in 0..n {
            assert_eq!(got[r], refbufs[r], "rank {r}");
        }
    }

    #[test]
    fn striped_allreduce_bitwise_across_edge_lengths() {
        // Lengths around the stripe boundaries: shorter than the group
        // (empty tail stripes), exactly divisible, off-by-one, and a
        // value-pattern where f32 addition order matters.
        for n in [2usize, 3, 4, 8] {
            for len in [0usize, 1, n - 1, n, n + 1, 37, 1 << 10] {
                let got = run_threads(n, len, |c, buf| c.all_reduce_mean(buf));
                let mut refbufs: Vec<Vec<f32>> = (0..n)
                    .map(|r| (0..len).map(|i| (r * len + i) as f32).collect())
                    .collect();
                let mut refs: Vec<&mut [f32]> =
                    refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                group::all_reduce_mean(&mut refs);
                for r in 0..n {
                    assert_eq!(got[r], refbufs[r], "n={n} len={len} rank {r}");
                }
            }
        }
    }

    #[test]
    fn striped_allreduce_order_sensitive_values_bitwise() {
        // Magnitude-staggered values make f32 addition order observable:
        // any deviation from the rank-0..n fold changes the result.
        let n = 4;
        let len = 23;
        let comms = ThreadComm::group(n);
        let make = |r: usize| -> Vec<f32> {
            (0..len)
                .map(|i| {
                    let m = [1e8f32, 1.0, -1e8, 3.0][r];
                    m + (i as f32) * 0.125
                })
                .collect()
        };
        let mut got = vec![Vec::new(); n];
        let make = &make;
        std::thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|c| {
                    s.spawn(move || {
                        let mut buf = make(c.rank());
                        c.all_reduce_mean(&mut buf);
                        buf
                    })
                })
                .collect();
            for (r, h) in handles.into_iter().enumerate() {
                got[r] = h.join().unwrap();
            }
        });
        let mut refbufs: Vec<Vec<f32>> = (0..n).map(make).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_reduce_mean(&mut refs);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_allgather_matches_sequential() {
        let n = 3;
        let len = 10;
        let spec = ShardSpec::new(len, n);
        let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
        let sh = shards.clone();
        let got = run_threads(n, len, move |c, buf| c.all_gather(buf, &sh));
        let mut refbufs: Vec<Vec<f32>> =
            (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
        let mut refs: Vec<&mut [f32]> = refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        group::all_gather(&mut refs, &shards);
        assert_eq!(got, refbufs);
    }

    #[test]
    fn threaded_reduce_scatter_matches_sequential() {
        for (n, len) in [(4usize, 16usize), (3, 7), (8, 8), (2, 1)] {
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let sh = shards.clone();
            let got = run_threads(n, len, move |c, buf| c.reduce_scatter_mean(buf, &sh));
            let mut refbufs: Vec<Vec<f32>> =
                (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_mean(&mut refs, &shards);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_reduce_scatter_sum_matches_sequential() {
        for (n, len) in [(4usize, 16usize), (3, 7), (2, 1)] {
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let sh = shards.clone();
            let got = run_threads(n, len, move |c, buf| c.reduce_scatter_sum(buf, &sh));
            let mut refbufs: Vec<Vec<f32>> =
                (0..n).map(|r| (0..len).map(|i| (r * len + i) as f32).collect()).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_sum(&mut refs, &shards);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_reduce_scatter_q8_matches_sequential_bitwise() {
        // Chunk-remainder lengths and magnitude-staggered values: any
        // deviation in quantize formulas or fold order shows up bitwise.
        use crate::tensor::QUANT_CHUNK;
        for (n, len) in [
            (4usize, 2 * QUANT_CHUNK),
            (3, QUANT_CHUNK + 7),
            (2, 1),
            (4, 3 * QUANT_CHUNK + 1),
        ] {
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let make = |r: usize| -> Vec<f32> {
                (0..len)
                    .map(|i| {
                        [1e3f32, -3.7, 0.01, 42.0][r % 4]
                            * (1.0 + (i as f32) * 0.37).sin()
                    })
                    .collect()
            };
            let comms = ThreadComm::group(n);
            let mut got = vec![Vec::new(); n];
            let (sh, mk) = (&shards, &make);
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let mut buf = mk(c.rank());
                            c.reduce_scatter_mean_q8(&mut buf, sh);
                            buf
                        })
                    })
                    .collect();
                for (r, h) in handles.into_iter().enumerate() {
                    got[r] = h.join().unwrap();
                }
            });
            let mut refbufs: Vec<Vec<f32>> = (0..n).map(mk).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_mean_q8(&mut refs, &shards);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_reduce_scatter_weighted_matches_sequential() {
        // Magnitude-staggered values + a zero weight: any deviation from
        // the ascending-rank skip-zero fold changes the f32 result.
        for (n, len) in [(4usize, 23usize), (3, 5), (1, 4)] {
            let weights: Vec<f32> =
                (0..n).map(|r| if r == 1 { 0.0 } else { 0.3 + r as f32 * 0.21 }).collect();
            let spec = ShardSpec::new(len, n);
            let shards: Vec<_> = (0..n).map(|r| spec.range(r)).collect();
            let make = |r: usize| -> Vec<f32> {
                (0..len)
                    .map(|i| [1e7f32, 3.0, -1e7, 5.0][r % 4] + (i as f32) * 0.125)
                    .collect()
            };
            let comms = ThreadComm::group(n);
            let mut got = vec![Vec::new(); n];
            let (sh, ws, mk) = (&shards, &weights, &make);
            std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        s.spawn(move || {
                            let mut buf = mk(c.rank());
                            c.reduce_scatter_weighted(&mut buf, sh, ws);
                            buf
                        })
                    })
                    .collect();
                for (r, h) in handles.into_iter().enumerate() {
                    got[r] = h.join().unwrap();
                }
            });
            let mut refbufs: Vec<Vec<f32>> = (0..n).map(mk).collect();
            let mut refs: Vec<&mut [f32]> =
                refbufs.iter_mut().map(|b| b.as_mut_slice()).collect();
            group::reduce_scatter_weighted(&mut refs, &shards, &weights);
            assert_eq!(got, refbufs, "n={n} len={len}");
        }
    }

    #[test]
    fn threaded_broadcast() {
        let got = run_threads(3, 5, |c, buf| c.broadcast(buf, 2));
        let expect: Vec<f32> = (0..5).map(|i| (2 * 5 + i) as f32).collect();
        for b in &got {
            assert_eq!(b, &expect);
        }
    }

    #[test]
    fn repeated_ops_no_deadlock() {
        let got = run_threads(4, 8, |c, buf| {
            for _ in 0..25 {
                c.all_reduce_mean(buf);
                c.barrier();
                c.broadcast(buf, 1);
            }
        });
        for b in &got[1..] {
            assert_eq!(b, &got[0]);
        }
    }

    // --- fallible surface ------------------------------------------------

    use crate::collectives::{Collective, CommError};
    use std::time::Duration;

    #[test]
    fn try_barrier_times_out_without_quorum() {
        // Two live ranks; only one shows up.
        let comms = ThreadComm::group(2);
        let got = comms[0].try_barrier(Duration::from_millis(40));
        assert!(
            matches!(got, Err(CommError::Timeout { op: "barrier", .. })),
            "{got:?}"
        );
        // The timed-out arrival was undone: a later full rendezvous works.
        let (c0, c1) = (&comms[0], &comms[1]);
        std::thread::scope(|s| {
            let a = s.spawn(move || c0.try_barrier(Duration::from_secs(5)));
            let b = s.spawn(move || c1.try_barrier(Duration::from_secs(5)));
            assert_eq!(a.join().unwrap(), Ok(()));
            assert_eq!(b.join().unwrap(), Ok(()));
        });
    }

    #[test]
    fn mark_failed_releases_a_blocked_rendezvous() {
        let comms = ThreadComm::group(2);
        let (c0, c1) = (&comms[0], &comms[1]);
        std::thread::scope(|s| {
            let h = s.spawn(move || c0.try_barrier(Duration::from_secs(10)));
            // Rank 1 dies while rank 0 waits; the waiter must re-count
            // the quorum and complete instead of riding out the timeout.
            std::thread::sleep(Duration::from_millis(30));
            c1.mark_failed(1);
            assert_eq!(h.join().unwrap(), Ok(()));
        });
        assert_eq!(comms[0].live_ranks(), 1);
    }

    #[test]
    fn degraded_all_reduce_means_over_live_ranks() {
        let comms = ThreadComm::group(3);
        comms[0].mark_failed(2);
        let (c0, c1) = (&comms[0], &comms[1]);
        let t = Duration::from_secs(5);
        std::thread::scope(|s| {
            let a = s.spawn(move || {
                let mut buf = vec![1.0f32; 7];
                c0.try_all_reduce_mean(&mut buf, t).map(|_| buf)
            });
            let b = s.spawn(move || {
                let mut buf = vec![2.0f32; 7];
                c1.try_all_reduce_mean(&mut buf, t).map(|_| buf)
            });
            // Mean over the two live ranks, the dead rank excluded.
            assert_eq!(a.join().unwrap().unwrap(), vec![1.5f32; 7]);
            assert_eq!(b.join().unwrap().unwrap(), vec![1.5f32; 7]);
        });
    }

    #[test]
    fn dead_root_and_dead_shard_owner_fail_deterministically() {
        let comms = ThreadComm::group(2);
        comms[0].mark_failed(1);
        let mut buf = vec![0.0f32; 4];
        assert_eq!(
            comms[0].try_broadcast(&mut buf, 1, Duration::from_millis(10)),
            Err(CommError::PeerFailed { rank: 1 })
        );
        let shards = [(0usize, 2usize), (2, 2)];
        let mut full = vec![0.0f32; 4];
        assert_eq!(
            comms[0].try_all_gather(&mut full, &shards, Duration::from_millis(10)),
            Err(CommError::PeerFailed { rank: 1 })
        );
        // A broadcast from a live root among the survivors still works
        // (sole survivor: trivially complete).
        assert_eq!(comms[0].try_broadcast(&mut buf, 0, Duration::from_millis(10)), Ok(()));
    }

    #[test]
    fn degraded_reduce_scatter_means_over_live_ranks() {
        let comms = ThreadComm::group(3);
        comms[0].mark_failed(1);
        let shards = [(0usize, 2usize), (2, 2), (4, 2)];
        let (c0, c2) = (&comms[0], &comms[2]);
        let t = Duration::from_secs(5);
        std::thread::scope(|s| {
            let a = s.spawn(move || {
                let mut full = vec![2.0f32; 6];
                c0.try_reduce_scatter_mean(&mut full, &shards, t).map(|_| full)
            });
            let b = s.spawn(move || {
                let mut full = vec![4.0f32; 6];
                c2.try_reduce_scatter_mean(&mut full, &shards, t).map(|_| full)
            });
            let a = a.join().unwrap().unwrap();
            let b = b.join().unwrap().unwrap();
            // Own shard holds the live mean (2+4)/2; the rest untouched.
            assert_eq!(a, vec![3.0, 3.0, 2.0, 2.0, 2.0, 2.0]);
            assert_eq!(b, vec![4.0, 4.0, 4.0, 4.0, 3.0, 3.0]);
        });
    }

    #[test]
    fn shutdown_wakes_waiters_and_poisons_later_ops() {
        let comms = ThreadComm::group(2);
        let (c0, c1) = (&comms[0], &comms[1]);
        std::thread::scope(|s| {
            let h = s.spawn(move || c0.try_barrier(Duration::from_secs(10)));
            std::thread::sleep(Duration::from_millis(30));
            c1.shutdown();
            assert_eq!(h.join().unwrap(), Err(CommError::Shutdown));
        });
        let mut buf = vec![0.0f32; 2];
        assert_eq!(
            comms[1].try_all_reduce_mean(&mut buf, Duration::from_millis(10)),
            Err(CommError::Shutdown)
        );
    }
}
