//! # edit-train — EDiT reproduction (ICLR 2025, Ant Group)
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *EDiT: A Local-SGD-Based Efficient Distributed Training Method for
//! Large Language Models*:
//!
//!  * **Layer 3 (this crate)** — the paper's coordination contribution:
//!    the [`coordinator`] implements the EDiT synchronization algorithm
//!    (Alg. 1), the pseudo-gradient penalty (Alg. 2), the asynchronous
//!    A-EDiT variant, and all baselines the paper compares against (DDP,
//!    Post Local SGD, DiLoCo, CO2, CO2*), over an FSDP-style device mesh.
//!  * **Layer 2** — a Llama-style decoder in JAX
//!    (`python/compile/model.py`), AOT-lowered to HLO text and executed
//!    through [`runtime`] on the PJRT CPU client. Python never runs at
//!    training time.
//!  * **Layer 1** — Pallas kernels (`python/compile/kernels/`): tiled
//!    online-softmax flash attention (fwd+bwd) inside the model, and the
//!    fused penalty combine callable from Rust.
//!
//! The [`simulator`] reproduces the paper's A100-cluster throughput
//! tables analytically (Table 2, Fig. 5, Fig. 9, Table 6); [`data`]
//! provides the synthetic corpus substrate; [`collectives`] the
//! deterministic communication substrate with its α-β cost model.
//!
//! Training strategies are described by the compositional
//! [`coordinator::spec::MethodSpec`] descriptor (named presets +
//! `custom:` grammar). See the repo-root README.md for the quickstart
//! and the method-zoo axes table.

pub mod bench;
pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod experiments;
pub mod fault;
pub mod metrics;
pub mod runtime;
pub mod simulator;
pub mod tensor;
pub mod testing;
pub mod util;
