//! Analytic cluster simulator — regenerates the paper's throughput
//! evaluation (Table 2, Fig. 5, Table 6) for hardware we do not have.
//!
//! One simulated inner step on the A100 mesh decomposes into
//!   compute            tokens · flops/token / (peak · mfu)
//!   FSDP comm          intra-node all-gather ×2 + reduce-scatter,
//!                      mostly overlapped (exposed fraction 10%)
//!   DDP all-reduce     inter-node gradient all-reduce (Baseline only),
//!                      overlappable with backward up to `hide budget`
//!   sync exposed       per-method residual at every τ-th step
//!                      (StepModel::sync_exposed — same formulas the
//!                      numerics trainer charges)
//! plus the straggler scenarios of §4.3: a random or consistent node
//! pause of `lag` seconds per step, and the limited-bandwidth scenario
//! (inter-node comms repeated `repeat`×).

use crate::collectives::{CollOp, CostModel, Topology};
use crate::coordinator::{MeshSpec, Method, MethodSpec};

use super::memory::{self, MemoryBreakdown};
use super::scales::{ScaleSpec, A100_MEM_BYTES, A100_PEAK_FLOPS};
use super::stepmodel::StepModel;

/// Straggler scenario (Fig. 5 columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scenario {
    Normal,
    RandomStraggler { lag: f64 },
    ConsistentStraggler { lag: f64 },
    LimitedBandwidth { repeat: u32 },
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Strategy descriptor — every per-method branch below prices its
    /// axes, so custom `MethodSpec`s simulate exactly like presets.
    pub spec: MethodSpec,
    /// Reporting label ("edit", "custom:base=edit,penalty=off", ...).
    pub label: String,
    pub scale: ScaleSpec,
    pub mesh: MeshSpec,
    pub topo: Topology,
    /// Sync interval in inner steps (Table 2 uses 5; Fig. 5 uses 128).
    pub tau: u64,
    /// Tokens per GPU per inner step (sequences × context).
    pub tokens_per_gpu: f64,
    pub scenario: Scenario,
}

impl SimConfig {
    /// Table 2 setting: two A100 nodes (8×2 mesh), τ=5, 2 sequences/GPU.
    pub fn table2(method: Method, scale: ScaleSpec) -> Self {
        Self::table2_spec(method.spec(), method.name(), scale)
    }

    /// [`Self::table2`] for an arbitrary strategy descriptor (the
    /// `custom:` ablation rows).
    pub fn table2_spec(spec: MethodSpec, label: impl Into<String>, scale: ScaleSpec) -> Self {
        Self {
            spec,
            label: label.into(),
            scale,
            mesh: MeshSpec::new(8, 2),
            topo: Topology::a100(),
            tau: 5,
            tokens_per_gpu: 2.0 * 4096.0,
            scenario: Scenario::Normal,
        }
    }

    /// Fig. 5 / Table 6 setting: eight nodes (8×8 mesh), τ=128, Llama 7B,
    /// 4 sequences/GPU (calibrated to the paper's ~225 TFLOPS baseline;
    /// EDiT/A-EDiT offload their sharded extra state at this size).
    pub fn fig5(method: Method, scenario: Scenario) -> Self {
        Self::fig5_spec(method.spec(), method.name(), scenario)
    }

    /// [`Self::fig5`] for an arbitrary strategy descriptor.
    pub fn fig5_spec(spec: MethodSpec, label: impl Into<String>, scenario: Scenario) -> Self {
        Self {
            spec,
            label: label.into(),
            scale: ScaleSpec::by_name("7B").unwrap(),
            mesh: MeshSpec::new(8, 8),
            topo: Topology::a100(),
            tau: 128,
            tokens_per_gpu: 4.0 * 4096.0,
            scenario,
        }
    }
}

#[derive(Debug, Clone)]
pub struct SimResult {
    /// The simulated strategy's label (`SimConfig::label`).
    pub label: String,
    /// None on OOM.
    pub tokens_per_sec: Option<f64>,
    pub tflops_per_gpu: Option<f64>,
    pub step_seconds: Option<f64>,
    pub memory: MemoryBreakdown,
    pub oom: bool,
}

impl SimResult {
    pub fn cell(&self) -> String {
        match (self.tokens_per_sec, self.tflops_per_gpu) {
            (Some(tput), Some(tf)) => format!("{:.2e}/{:.0}", tput, tf),
            _ => "OOM".to_string(),
        }
    }
}

/// Overlap headroom for REPEATED inter-node gradient all-reduces (the
/// limited-bandwidth scenario): repeats can hide behind this fraction
/// of the backward pass; the first instance is never hidden (it
/// completes after the last gradient bucket). Calibrated against the
/// paper's Table 6 bandwidth column.
const DDP_HIDE_FRACTION: f64 = 0.40;
/// Exposed fraction of the intra-node FSDP traffic.
const FSDP_EXPOSED: f64 = 0.10;
/// Gradients travel in bf16; pseudo-gradient sync state in fp32.
const GRAD_BYTES: f64 = 2.0;
const SYNC_BYTES: f64 = 4.0;

pub fn simulate(cfg: &SimConfig) -> SimResult {
    let memory = memory::breakdown(
        &cfg.spec,
        &cfg.scale,
        cfg.mesh.shard,
        cfg.tokens_per_gpu,
        A100_MEM_BYTES,
    );
    if memory.total() > A100_MEM_BYTES {
        return SimResult {
            label: cfg.label.clone(),
            tokens_per_sec: None,
            tflops_per_gpu: None,
            step_seconds: None,
            memory,
            oom: true,
        };
    }

    let inter_repeat = match cfg.scenario {
        Scenario::LimitedBandwidth { repeat } => repeat,
        _ => 0,
    };
    let cost = CostModel::new(cfg.topo).with_inter_repeat(inter_repeat);
    let flops_step = cfg.tokens_per_gpu * cfg.scale.flops_per_token();
    let compute = flops_step / (A100_PEAK_FLOPS * cfg.scale.a100_mfu());

    // FSDP traffic within the shard group (bf16 params/grads).
    let param_bytes_bf16 = (cfg.scale.params() as f64 * GRAD_BYTES) as usize;
    let shard_group = cfg.mesh.shard_group(0);
    let fsdp = 2.0 * cost.time(CollOp::AllGather, param_bytes_bf16, &shard_group)
        + cost.time(CollOp::ReduceScatter, param_bytes_bf16, &shard_group);
    let mut step = compute + FSDP_EXPOSED * fsdp;

    // Baseline / warmup: inter-node gradient all-reduce each step, each
    // GPU moving its P/M shard across its sync group; overlappable with
    // part of the backward pass.
    if !cfg.spec.is_local_sgd() {
        let sync_group = cfg.mesh.sync_group(0);
        let shard_bytes =
            (cfg.scale.params() as f64 * GRAD_BYTES / cfg.mesh.shard as f64) as usize;
        // `cost` already multiplies inter traffic by (repeat+1).
        let ar_total = cost.time(CollOp::AllReduce, shard_bytes, &sync_group);
        let ar_once = ar_total / (inter_repeat + 1) as f64;
        let hide = DDP_HIDE_FRACTION * compute;
        step += (ar_total - hide).max(ar_once);
    }

    // Periodic synchronization residual, amortized over τ.
    if cfg.spec.is_local_sgd() {
        let sm = StepModel {
            mesh: cfg.mesh,
            cost,
            param_bytes: (cfg.scale.params() as f64 * SYNC_BYTES) as usize,
            compute,
            cpu_offload: memory.offloaded,
        };
        step += sm.sync_exposed(&cfg.spec) / cfg.tau as f64;
    }

    // Straggler scenarios (§4.3). τ-round analysis, one lagging node of
    // the N replicas per step. The trigger axis decides the barrier
    // behavior: no periodic sync = fully synchronous DDP (everyone
    // waits every step); time-based/probabilistic triggers never
    // barrier (A-EDiT/PALSGD); the step-τ trigger barriers per round.
    step += match cfg.scenario {
        Scenario::Normal | Scenario::LimitedBandwidth { .. } => 0.0,
        Scenario::RandomStraggler { lag } => {
            let n = cfg.mesh.replicas as f64;
            if !cfg.spec.is_local_sgd() {
                // Synchronous: someone always lags, everyone waits.
                lag
            } else if cfg.spec.trigger.time_based() {
                // No sync barrier stretch; only the victim's share of
                // wall time is lost (it contributes fewer steps).
                lag / n
            } else {
                // Step-synced local methods: per-round delay is the MAX
                // over nodes of Binomial(τ, 1/n) lag sums.
                let tau = cfg.tau as f64;
                let mean = tau / n;
                let sd = (tau * (1.0 / n) * (1.0 - 1.0 / n)).sqrt();
                let max_extra = sd * (2.0 * (cfg.mesh.replicas as f64).ln()).sqrt();
                (mean + max_extra) * lag / tau
            }
        }
        Scenario::ConsistentStraggler { lag } => {
            if !cfg.spec.is_local_sgd() {
                lag
            } else if cfg.spec.trigger.time_based() {
                // The slow replica just does fewer steps; cluster
                // throughput scales by the mean step-rate.
                let n = cfg.mesh.replicas as f64;
                let slow_rate = step / (step + lag);
                // Convert rate loss into an equivalent per-step stretch.
                let eff = ((n - 1.0) + slow_rate) / n;
                step * (1.0 / eff - 1.0)
            } else {
                // Step-synced: the same node accumulates lag every step
                // and the others wait at each sync — full lag per step.
                lag
            }
        }
    };

    let tokens_cluster = cfg.tokens_per_gpu * cfg.mesh.workers() as f64;
    SimResult {
        label: cfg.label.clone(),
        tokens_per_sec: Some(tokens_cluster / step),
        tflops_per_gpu: Some(flops_step / step / 1e12),
        step_seconds: Some(step),
        memory,
        oom: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(method: Method, scale: &str) -> SimResult {
        simulate(&SimConfig::table2(method, ScaleSpec::by_name(scale).unwrap()))
    }

    #[test]
    #[ignore = "calibration dump; run with --ignored --nocapture"]
    fn calibration_dump() {
        for scale in ["350M", "1B", "3B", "7B"] {
            let row: Vec<String> =
                Method::ALL.iter().map(|&m| t2(m, scale).cell()).collect();
            println!("{scale:>5}: {}", row.join("  "));
        }
        for (name, sc) in [
            ("normal", Scenario::Normal),
            ("rand1.5", Scenario::RandomStraggler { lag: 1.5 }),
            ("cons2.5", Scenario::ConsistentStraggler { lag: 2.5 }),
            ("bw r=20", Scenario::LimitedBandwidth { repeat: 20 }),
            ("bw r=40", Scenario::LimitedBandwidth { repeat: 40 }),
        ] {
            let cells: Vec<String> = [Method::Baseline, Method::Edit, Method::AEdit]
                .iter()
                .map(|&m| {
                    format!("{:.1}", simulate(&SimConfig::fig5(m, sc)).tflops_per_gpu.unwrap())
                })
                .collect();
            println!("fig5 {name:>8}: base/edit/aedit = {}", cells.join(" / "));
        }
    }

    #[test]
    fn table2_oom_cells() {
        use Method::*;
        assert!(!t2(Baseline, "7B").oom);
        assert!(!t2(Edit, "7B").oom && !t2(AEdit, "7B").oom);
        assert!(t2(PostLocalSgd, "3B").oom);
        assert!(t2(DiLoCo, "3B").oom);
        assert!(t2(Co2, "1B").oom);
        assert!(t2(Co2Star, "3B").oom);
        assert!(!t2(Co2, "350M").oom);
    }

    #[test]
    fn table2_local_sgd_beats_baseline() {
        for scale in ["350M", "1B", "3B", "7B"] {
            let base = t2(Method::Baseline, scale).tflops_per_gpu.unwrap();
            let edit = t2(Method::Edit, scale).tflops_per_gpu.unwrap();
            assert!(edit > base, "{scale}: edit {edit} <= base {base}");
            // Gains are single-digit percent at τ=5 (paper: +3..8%).
            assert!(edit / base < 1.2, "{scale}: ratio {}", edit / base);
        }
    }

    #[test]
    fn table2_baseline_tflops_shape() {
        // Paper: 107 / 146 / 177 / 200 TFLOPS. Require the same rising
        // shape within ±20% per cell.
        let want = [107.0, 146.0, 177.0, 200.0];
        for (scale, w) in ["350M", "1B", "3B", "7B"].iter().zip(want) {
            let got = t2(Method::Baseline, scale).tflops_per_gpu.unwrap();
            assert!((got / w - 1.0).abs() < 0.2, "{scale}: got {got}, want ~{w}");
        }
    }

    #[test]
    fn co2_fastest_when_it_fits_and_edit_close() {
        let co2 = t2(Method::Co2, "350M").tflops_per_gpu.unwrap();
        let edit = t2(Method::Edit, "350M").tflops_per_gpu.unwrap();
        let co2s = t2(Method::Co2Star, "350M").tflops_per_gpu.unwrap();
        assert!(co2 >= edit);
        assert!((co2 - edit) / co2 < 0.03, "EDiT within ~-0.5% of CO2 (paper)");
        assert!(co2s < co2, "CO2* pays exposed shard handling");
    }

    #[test]
    fn fig5_random_straggler_ordering() {
        let lag = 2.5;
        let base = simulate(&SimConfig::fig5(Method::Baseline, Scenario::RandomStraggler { lag }));
        let edit = simulate(&SimConfig::fig5(Method::Edit, Scenario::RandomStraggler { lag }));
        let aedit = simulate(&SimConfig::fig5(Method::AEdit, Scenario::RandomStraggler { lag }));
        let b = base.tflops_per_gpu.unwrap();
        let e = edit.tflops_per_gpu.unwrap();
        let a = aedit.tflops_per_gpu.unwrap();
        assert!(a > e && e > b, "a={a} e={e} b={b}");
        // Paper: baseline drops to ~150, EDiT stays ~220.
        assert!(b < 0.75 * e);
    }

    #[test]
    fn fig5_consistent_straggler_only_aedit_survives() {
        let lag = 3.5;
        let edit = simulate(&SimConfig::fig5(Method::Edit, Scenario::ConsistentStraggler { lag }))
            .tflops_per_gpu
            .unwrap();
        let aedit = simulate(&SimConfig::fig5(Method::AEdit, Scenario::ConsistentStraggler { lag }))
            .tflops_per_gpu
            .unwrap();
        let normal = simulate(&SimConfig::fig5(Method::AEdit, Scenario::Normal))
            .tflops_per_gpu
            .unwrap();
        assert!(aedit > 0.9 * normal, "A-EDiT nearly unaffected");
        assert!(edit < 0.75 * aedit, "EDiT visibly degraded");
    }

    #[test]
    fn fig5_bandwidth_hits_baseline_only() {
        let r = Scenario::LimitedBandwidth { repeat: 30 };
        let base0 = simulate(&SimConfig::fig5(Method::Baseline, Scenario::Normal))
            .tflops_per_gpu
            .unwrap();
        let base = simulate(&SimConfig::fig5(Method::Baseline, r)).tflops_per_gpu.unwrap();
        let edit0 =
            simulate(&SimConfig::fig5(Method::Edit, Scenario::Normal)).tflops_per_gpu.unwrap();
        let edit = simulate(&SimConfig::fig5(Method::Edit, r)).tflops_per_gpu.unwrap();
        assert!(base < 0.6 * base0, "baseline collapses: {base} vs {base0}");
        assert!(edit > 0.97 * edit0, "EDiT unaffected: {edit} vs {edit0}");
    }

    #[test]
    fn fig5_baseline_absolute_scale() {
        // Paper Table 6: baseline ~225 TFLOPS at lag 0; ~85 at repeat=40.
        let b0 = simulate(&SimConfig::fig5(Method::Baseline, Scenario::Normal))
            .tflops_per_gpu
            .unwrap();
        assert!((b0 / 225.0 - 1.0).abs() < 0.2, "{b0}");
        let b40 = simulate(&SimConfig::fig5(
            Method::Baseline,
            Scenario::LimitedBandwidth { repeat: 40 },
        ))
        .tflops_per_gpu
        .unwrap();
        assert!((b40 / 85.0 - 1.0).abs() < 0.35, "{b40}");
    }
}
