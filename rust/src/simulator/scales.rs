//! The paper's model scales (Table 3) and FLOPs/parameter arithmetic.

/// Architecture of one paper-scale Llama model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleSpec {
    pub name: &'static str,
    pub num_layers: usize,
    pub hidden: usize,
    pub intermediate: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
}

impl ScaleSpec {
    /// Paper Table 3 configurations (all 32 layers, vocab 79,800,
    /// context 4,096).
    pub const PAPER: [ScaleSpec; 4] = [
        ScaleSpec {
            name: "350M",
            num_layers: 32,
            hidden: 768,
            intermediate: 2048,
            heads: 6,
            vocab: 79_800,
            seq: 4096,
        },
        ScaleSpec {
            name: "1B",
            num_layers: 32,
            hidden: 1536,
            intermediate: 4096,
            heads: 12,
            vocab: 79_800,
            seq: 4096,
        },
        ScaleSpec {
            name: "3B",
            num_layers: 32,
            hidden: 2560,
            intermediate: 6912,
            heads: 20,
            vocab: 79_800,
            seq: 4096,
        },
        ScaleSpec {
            name: "7B",
            num_layers: 32,
            hidden: 4096,
            intermediate: 11_008,
            heads: 32,
            vocab: 79_800,
            seq: 4096,
        },
    ];

    pub fn by_name(name: &str) -> Option<ScaleSpec> {
        Self::PAPER
            .iter()
            .copied()
            .find(|s| s.name.eq_ignore_ascii_case(name))
    }

    /// Parameter count (same formula as the L2 model: embed + untied head
    /// + per-layer 2 norms + 4 attention mats + 3 SwiGLU mats + final norm).
    pub fn params(&self) -> u64 {
        let (d, f, v, l) = (
            self.hidden as u64,
            self.intermediate as u64,
            self.vocab as u64,
            self.num_layers as u64,
        );
        2 * v * d + d + l * (2 * d + 4 * d * d + 3 * d * f)
    }

    /// Training FLOPs per token: the standard 6·P matmul term plus the
    /// causal-attention term 6·L·S·D.
    pub fn flops_per_token(&self) -> f64 {
        6.0 * self.params() as f64
            + 6.0 * (self.num_layers * self.seq * self.hidden) as f64
    }

    /// Achieved compute MFU on A100 (bf16 peak 312 TFLOPS), calibrated so
    /// the simulated Baseline reproduces the paper's Table 2 TFLOPS
    /// column (small models are launch/HBM bound; utilization rises with
    /// arithmetic intensity). Linear interpolation in log10(params).
    pub fn a100_mfu(&self) -> f64 {
        // (log10 params, compute-only MFU) anchors.
        const PTS: [(f64, f64); 4] =
            [(8.64, 0.375), (9.17, 0.50), (9.55, 0.60), (9.93, 0.675)];
        let x = (self.params() as f64).log10();
        if x <= PTS[0].0 {
            return PTS[0].1;
        }
        for w in PTS.windows(2) {
            if x <= w[1].0 {
                let t = (x - w[0].0) / (w[1].0 - w[0].0);
                return w[0].1 + t * (w[1].1 - w[0].1);
            }
        }
        PTS[3].1
    }
}

pub const A100_PEAK_FLOPS: f64 = 312e12;
/// 40 GB A100s minus CUDA context / NCCL buffers / fragmentation (~15%).
pub const A100_MEM_BYTES: f64 = 34e9;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_near_nominal() {
        // Vocab 79,800 adds a large embedding; total should be within
        // ~45% of the nominal size label (as for the real Llama configs).
        for (spec, nominal) in ScaleSpec::PAPER.iter().zip([0.35e9, 1.0e9, 3.0e9, 7.0e9]) {
            let p = spec.params() as f64;
            assert!(
                (p / nominal) > 0.8 && (p / nominal) < 1.6,
                "{}: {p}",
                spec.name
            );
        }
    }

    #[test]
    fn params_monotone() {
        let ps: Vec<u64> = ScaleSpec::PAPER.iter().map(|s| s.params()).collect();
        assert!(ps.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn mfu_rises_with_scale() {
        let mfus: Vec<f64> = ScaleSpec::PAPER.iter().map(|s| s.a100_mfu()).collect();
        assert!(mfus.windows(2).all(|w| w[0] < w[1]));
        assert!(mfus[0] > 0.3 && mfus[3] < 0.7);
    }

    #[test]
    fn by_name() {
        assert_eq!(ScaleSpec::by_name("7b").unwrap().hidden, 4096);
        assert!(ScaleSpec::by_name("13B").is_none());
    }

    #[test]
    fn flops_dominated_by_param_term() {
        let s = ScaleSpec::by_name("7B").unwrap();
        let param_term = 6.0 * s.params() as f64;
        assert!(s.flops_per_token() < 1.3 * param_term);
    }
}
