//! Per-GPU memory model — reproduces Table 2's OOM column, priced from
//! the [`MethodSpec`] strategy axes.
//!
//! The decisive structural fact (paper §2, Related Work): the
//! All-Reduce-based Local SGD methods (Post Local SGD, DiLoCo, CO2,
//! CO2*) hold COMPLETE model parameters/gradients/optimizer state on
//! every GPU — they do not compose with ZeRO-3 sharding — while
//! Baseline (plain ZeRO-3) and the layer-wise strategies (EDiT, A-EDiT,
//! PALSGD) shard everything across the model shard group of size M
//! ([`MethodSpec::model_sharded`]).  Extra local-SGD state decomposes
//! along the axes:
//!   * the θ_t anchor (+4P bytes), divided by M when `shard_anchor`;
//!   * the outer momentum (+4P when the outer optimizer carries one),
//!     divided by M when `shard_outer_state`;
//!   * an async in-flight pseudo-gradient snapshot (+4P) when the outer
//!     update is overlapped (`outer_staleness > 0`) with full state —
//!     pinned on GPU, which is what keeps CO2 from offloading.
//!
//! This reproduces the seed per-method table exactly:
//!   PLS    anchor only, full                    (+4P bytes)
//!   DiLoCo anchor+momentum, full                (+8P, CPU-offloadable)
//!   CO2    anchor+momentum+async send snapshot  (+12P, pinned on GPU)
//!   CO2*   anchor+momentum, sharded             (+8P/M)
//!   EDiT   anchor+momentum, sharded             (+8P/M, CPU-offloadable)
//!
//! Mixed precision accounting per parameter: sharded (ZeRO-3) methods
//! pay bf16 weights (2) + fp32 master (4) + fp32 Adam m,v (8) + bf16
//! grads (2) = 16 bytes over M; unsharded (All-Reduce-based) methods pay
//! the same plus a bf16 compute copy = 18 bytes, NOT divided.

use super::scales::ScaleSpec;
use crate::coordinator::spec::MethodSpec;

const SHARDED_STATE_BYTES_PER_PARAM: f64 = 16.0;
const UNSHARDED_STATE_BYTES_PER_PARAM: f64 = 18.0;
/// Extra bytes per parameter for one fp32 copy (anchor / momentum /
/// async snapshot each cost one).
const FP32_COPY: f64 = 4.0;
/// Activation bytes per token per layer per hidden unit (bf16 with flash
/// attention and selective recompute).
const ACT_FACTOR: f64 = 6.0;
/// CUDA/XLA workspace + fragmentation allowance.
const WORKSPACE: f64 = 2e9;

#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub model_state: f64,
    pub local_sgd_extra: f64,
    pub activations: f64,
    pub workspace: f64,
    /// Extra state resides on CPU (DiLoCo-at-1B style offload).
    pub offloaded: bool,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.model_state + self.local_sgd_extra + self.activations + self.workspace
    }
}

/// Extra local-SGD bytes per parameter for `spec` with shard-group size
/// `m` — the axis decomposition documented in the module header.
fn extra_bytes_per_param(spec: &MethodSpec, m: usize) -> f64 {
    if !spec.is_local_sgd() {
        return 0.0;
    }
    let anchor = if spec.shard_anchor {
        FP32_COPY / m as f64
    } else {
        FP32_COPY
    };
    let momentum = if spec.outer.needs_momentum() {
        if spec.shard_outer_state {
            FP32_COPY / m as f64
        } else {
            FP32_COPY
        }
    } else {
        0.0
    };
    // Overlapped outer update with full state: the in-flight async send
    // snapshot is pinned on GPU (CO2). The sharded variant (CO2*) pays
    // exposed shard handling at sync time instead of resident memory.
    let snapshot = if spec.outer_staleness > 0 && !spec.shard_outer_state {
        FP32_COPY
    } else {
        0.0
    };
    anchor + momentum + snapshot
}

/// Per-GPU memory for `spec` at `scale` with shard-group size `m` and
/// `tokens_per_gpu` tokens resident per step. Offload is applied
/// automatically (when supported) if the GPU budget would overflow.
pub fn breakdown(
    spec: &MethodSpec,
    scale: &ScaleSpec,
    m: usize,
    tokens_per_gpu: f64,
    budget: f64,
) -> MemoryBreakdown {
    let p = scale.params() as f64;
    let model_state = if spec.model_sharded() {
        SHARDED_STATE_BYTES_PER_PARAM * p / m as f64
            // Gathered working set of ~2 layers of bf16 params (prefetch).
            + 2.0 * 2.0 * p / scale.num_layers as f64
    } else {
        UNSHARDED_STATE_BYTES_PER_PARAM * p
    };

    let mut local_sgd_extra = extra_bytes_per_param(spec, m) * p;

    let activations =
        ACT_FACTOR * tokens_per_gpu * (scale.num_layers as f64) * (scale.hidden as f64);

    let mut offloaded = false;
    let pre_total = model_state + local_sgd_extra + activations + WORKSPACE;
    if pre_total > budget && spec.extra_offloadable() && local_sgd_extra > 0.0 {
        offloaded = true;
        local_sgd_extra = 0.0;
    }

    MemoryBreakdown { model_state, local_sgd_extra, activations, workspace: WORKSPACE, offloaded }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Method;
    use crate::simulator::scales::A100_MEM_BYTES;

    fn scale(name: &str) -> ScaleSpec {
        ScaleSpec::by_name(name).unwrap()
    }

    /// tokens/GPU/step used in the Table-2 reproduction.
    const TOKENS: f64 = 2.0 * 4096.0;

    fn fits(method: Method, name: &str) -> bool {
        breakdown(&method.spec(), &scale(name), 8, TOKENS, A100_MEM_BYTES).total()
            <= A100_MEM_BYTES
    }

    #[test]
    fn table2_oom_pattern() {
        use Method::*;
        // Paper Table 2 (two A100 nodes, M=8): OOM cells.
        assert!(fits(Baseline, "7B"));
        assert!(fits(Edit, "7B") && fits(AEdit, "7B"));
        assert!(fits(PostLocalSgd, "1B") && !fits(PostLocalSgd, "3B"));
        assert!(fits(DiLoCo, "1B") && !fits(DiLoCo, "3B"));
        assert!(fits(Co2, "350M") && !fits(Co2, "1B"));
        assert!(fits(Co2Star, "1B") && !fits(Co2Star, "3B"));
    }

    #[test]
    fn axis_decomposition_reproduces_seed_per_method_extras() {
        use Method::*;
        // The historical hard-coded table, now derived from the axes.
        assert_eq!(extra_bytes_per_param(&Baseline.spec(), 8), 0.0);
        assert_eq!(extra_bytes_per_param(&PostLocalSgd.spec(), 8), 4.0);
        assert_eq!(extra_bytes_per_param(&DiLoCo.spec(), 8), 8.0);
        assert_eq!(extra_bytes_per_param(&Co2.spec(), 8), 12.0);
        assert_eq!(extra_bytes_per_param(&Co2Star.spec(), 8), 8.0 / 8.0);
        assert_eq!(extra_bytes_per_param(&Edit.spec(), 8), 8.0 / 8.0);
        assert_eq!(extra_bytes_per_param(&AEdit.spec(), 8), 8.0 / 8.0);
        // Arbitrary group sizes stay bitwise (4/m + 4/m == 8/m exactly).
        for m in [2usize, 3, 5, 7, 8, 16] {
            assert_eq!(
                extra_bytes_per_param(&Edit.spec(), m).to_bits(),
                (8.0 / m as f64).to_bits(),
                "m={m}"
            );
        }
    }

    #[test]
    fn diloco_1b_requires_offload() {
        let b = breakdown(&Method::DiLoCo.spec(), &scale("1B"), 8, TOKENS, A100_MEM_BYTES);
        assert!(b.offloaded, "paper: DiLoCo@1B staged extra state on CPU");
        let b350 =
            breakdown(&Method::DiLoCo.spec(), &scale("350M"), 8, TOKENS, A100_MEM_BYTES);
        assert!(!b350.offloaded);
    }

    #[test]
    fn edit_extra_is_sharded() {
        let e = breakdown(&Method::Edit.spec(), &scale("1B"), 8, TOKENS, f64::INFINITY);
        let c = breakdown(&Method::Co2.spec(), &scale("1B"), 8, TOKENS, f64::INFINITY);
        assert!(e.local_sgd_extra * 7.9 < c.local_sgd_extra);
    }

    #[test]
    fn sharding_helps_model_state() {
        let b1 = breakdown(&Method::Baseline.spec(), &scale("7B"), 1, TOKENS, f64::INFINITY);
        let b8 = breakdown(&Method::Baseline.spec(), &scale("7B"), 8, TOKENS, f64::INFINITY);
        assert!(b8.model_state < b1.model_state / 4.0);
    }

    #[test]
    fn totals_positive_and_ordered() {
        let b = breakdown(&Method::Edit.spec(), &scale("350M"), 8, TOKENS, A100_MEM_BYTES);
        assert!(b.total() > 0.0);
        assert!(b.activations > 0.0 && b.model_state > 0.0);
    }

    #[test]
    fn palsgd_prices_like_the_edit_family() {
        // The descriptor-registered strategy needs no new memory-model
        // code: its axes land in the EDiT bucket.
        let p = breakdown(&Method::Palsgd.spec(), &scale("7B"), 8, TOKENS, A100_MEM_BYTES);
        let e = breakdown(&Method::Edit.spec(), &scale("7B"), 8, TOKENS, A100_MEM_BYTES);
        assert_eq!(p.total().to_bits(), e.total().to_bits());
        assert!(p.total() <= A100_MEM_BYTES);
    }
}
