//! Per-GPU memory model — reproduces Table 2's OOM column.
//!
//! The decisive structural fact (paper §2, Related Work): the
//! All-Reduce-based Local SGD methods (Post Local SGD, DiLoCo, CO2,
//! CO2*) hold COMPLETE model parameters/gradients/optimizer state on
//! every GPU — they do not compose with ZeRO-3 sharding — while
//! Baseline (plain ZeRO-3) and EDiT/A-EDiT shard everything across the
//! model shard group of size M.  Extra local-SGD state (the θ_t anchor
//! and the outer momentum) is:
//!   PLS    anchor only, full                    (+4P bytes)
//!   DiLoCo anchor+momentum, full                (+8P, CPU-offloadable)
//!   CO2    anchor+momentum+async send snapshot  (+12P, pinned on GPU —
//!          the in-flight pseudo-gradient buffer is what the overlap
//!          needs, so it cannot offload)
//!   CO2*   anchor+momentum, sharded             (+8P/M)
//!   EDiT   anchor+momentum, sharded             (+8P/M, CPU-offloadable)
//!
//! Mixed precision accounting per parameter: sharded (ZeRO-3) methods
//! pay bf16 weights (2) + fp32 master (4) + fp32 Adam m,v (8) + bf16
//! grads (2) = 16 bytes over M; unsharded (All-Reduce-based) methods pay
//! the same plus a bf16 compute copy = 18 bytes, NOT divided.

use crate::coordinator::Method;
use super::scales::ScaleSpec;

const SHARDED_STATE_BYTES_PER_PARAM: f64 = 16.0;
const UNSHARDED_STATE_BYTES_PER_PARAM: f64 = 18.0;
/// Extra bytes per parameter for one fp32 (anchor) / two fp32 (anchor+momentum).
const ANCHOR: f64 = 4.0;
const ANCHOR_PLUS_MOMENTUM: f64 = 8.0;
/// CO2: anchor + momentum + fp32 async-send snapshot.
const CO2_EXTRA: f64 = 12.0;
/// Activation bytes per token per layer per hidden unit (bf16 with flash
/// attention and selective recompute).
const ACT_FACTOR: f64 = 6.0;
/// CUDA/XLA workspace + fragmentation allowance.
const WORKSPACE: f64 = 2e9;

#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub model_state: f64,
    pub local_sgd_extra: f64,
    pub activations: f64,
    pub workspace: f64,
    /// Extra state resides on CPU (DiLoCo-at-1B style offload).
    pub offloaded: bool,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.model_state + self.local_sgd_extra + self.activations + self.workspace
    }
}

/// Does `method` shard the *model* state (ZeRO-3) on this mesh?
pub fn model_sharded(method: Method) -> bool {
    matches!(method, Method::Baseline | Method::Edit | Method::AEdit)
}

/// Whether the extra state can be staged on CPU when tight.
pub fn extra_offloadable(method: Method) -> bool {
    matches!(method, Method::DiLoCo | Method::Edit | Method::AEdit)
}

/// Per-GPU memory for `method` at `scale` with shard-group size `m` and
/// `tokens_per_gpu` tokens resident per step. Offload is applied
/// automatically (when supported) if the GPU budget would overflow.
pub fn breakdown(
    method: Method,
    scale: &ScaleSpec,
    m: usize,
    tokens_per_gpu: f64,
    budget: f64,
) -> MemoryBreakdown {
    let p = scale.params() as f64;
    let model_state = if model_sharded(method) {
        SHARDED_STATE_BYTES_PER_PARAM * p / m as f64
            // Gathered working set of ~2 layers of bf16 params (prefetch).
            + 2.0 * 2.0 * p / scale.num_layers as f64
    } else {
        UNSHARDED_STATE_BYTES_PER_PARAM * p
    };

    let extra_per_param = match method {
        Method::Baseline => 0.0,
        Method::PostLocalSgd => ANCHOR,
        Method::DiLoCo => ANCHOR_PLUS_MOMENTUM,
        Method::Co2 => CO2_EXTRA,
        Method::Co2Star => ANCHOR_PLUS_MOMENTUM / m as f64,
        Method::Edit | Method::AEdit => ANCHOR_PLUS_MOMENTUM / m as f64,
    };
    let mut local_sgd_extra = extra_per_param * p;

    let activations =
        ACT_FACTOR * tokens_per_gpu * (scale.num_layers as f64) * (scale.hidden as f64);

    let mut offloaded = false;
    let pre_total = model_state + local_sgd_extra + activations + WORKSPACE;
    if pre_total > budget && extra_offloadable(method) && local_sgd_extra > 0.0 {
        offloaded = true;
        local_sgd_extra = 0.0;
    }

    MemoryBreakdown { model_state, local_sgd_extra, activations, workspace: WORKSPACE, offloaded }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::scales::A100_MEM_BYTES;

    fn scale(name: &str) -> ScaleSpec {
        ScaleSpec::by_name(name).unwrap()
    }

    /// tokens/GPU/step used in the Table-2 reproduction.
    const TOKENS: f64 = 2.0 * 4096.0;

    fn fits(method: Method, name: &str) -> bool {
        breakdown(method, &scale(name), 8, TOKENS, A100_MEM_BYTES).total()
            <= A100_MEM_BYTES
    }

    #[test]
    fn table2_oom_pattern() {
        use Method::*;
        // Paper Table 2 (two A100 nodes, M=8): OOM cells.
        assert!(fits(Baseline, "7B"));
        assert!(fits(Edit, "7B") && fits(AEdit, "7B"));
        assert!(fits(PostLocalSgd, "1B") && !fits(PostLocalSgd, "3B"));
        assert!(fits(DiLoCo, "1B") && !fits(DiLoCo, "3B"));
        assert!(fits(Co2, "350M") && !fits(Co2, "1B"));
        assert!(fits(Co2Star, "1B") && !fits(Co2Star, "3B"));
    }

    #[test]
    fn diloco_1b_requires_offload() {
        let b = breakdown(Method::DiLoCo, &scale("1B"), 8, TOKENS, A100_MEM_BYTES);
        assert!(b.offloaded, "paper: DiLoCo@1B staged extra state on CPU");
        let b350 = breakdown(Method::DiLoCo, &scale("350M"), 8, TOKENS, A100_MEM_BYTES);
        assert!(!b350.offloaded);
    }

    #[test]
    fn edit_extra_is_sharded() {
        let e = breakdown(Method::Edit, &scale("1B"), 8, TOKENS, f64::INFINITY);
        let c = breakdown(Method::Co2, &scale("1B"), 8, TOKENS, f64::INFINITY);
        assert!(e.local_sgd_extra * 7.9 < c.local_sgd_extra);
    }

    #[test]
    fn sharding_helps_model_state() {
        let b1 = breakdown(Method::Baseline, &scale("7B"), 1, TOKENS, f64::INFINITY);
        let b8 = breakdown(Method::Baseline, &scale("7B"), 8, TOKENS, f64::INFINITY);
        assert!(b8.model_state < b1.model_state / 4.0);
    }

    #[test]
    fn totals_positive_and_ordered() {
        let b = breakdown(Method::Edit, &scale("350M"), 8, TOKENS, A100_MEM_BYTES);
        assert!(b.total() > 0.0);
        assert!(b.activations > 0.0 && b.model_state > 0.0);
    }
}
