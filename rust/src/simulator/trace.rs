//! Synchronization-operation timeline traces (Fig. 9 reproduction).
//!
//! For each method, lay out the segments around one synchronization
//! boundary while training Llama 1B on the 8×8 mesh — the setting of
//! the paper's profiler screenshots — and render them as a text
//! timeline plus CSV rows. The exposed-delay column is the number the
//! paper quotes (PLS ~160 ms, CO2* ~300 ms, EDiT ~19 ms, CO2 ~0).

use crate::collectives::{CollOp, CostModel, Topology};
use crate::coordinator::{MeshSpec, Method};

use super::memory;
use super::scales::{ScaleSpec, A100_MEM_BYTES, A100_PEAK_FLOPS};
use super::stepmodel::StepModel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegKind {
    Compute,
    OverlappedComm,
    ExposedComm,
    CpuTransfer,
}

impl SegKind {
    pub fn glyph(&self) -> char {
        match self {
            SegKind::Compute => '#',
            SegKind::OverlappedComm => '~',
            SegKind::ExposedComm => '!',
            SegKind::CpuTransfer => '$',
        }
    }
}

#[derive(Debug, Clone)]
pub struct Segment {
    pub name: String,
    pub kind: SegKind,
    pub start: f64,
    pub dur: f64,
}

#[derive(Debug, Clone)]
pub struct Timeline {
    pub method: Method,
    pub segments: Vec<Segment>,
    /// Wall-time the sync adds on top of back-to-back compute steps.
    pub exposed: f64,
}

/// Build the sync-boundary timeline for `method` (Llama 1B, 8×8 mesh).
pub fn sync_timeline(method: Method) -> Timeline {
    let spec = method.spec();
    let scale = ScaleSpec::by_name("1B").unwrap();
    let mesh = MeshSpec::new(8, 8);
    let cost = CostModel::new(Topology::a100());
    let tokens = 2.0 * 4096.0;
    let compute = tokens * scale.flops_per_token() / (A100_PEAK_FLOPS * scale.a100_mfu());
    // Offload comes from the memory model at this scale instead of a
    // per-method special case (paper: DiLoCo@1B stages its extra state
    // on CPU; everything else fits or cannot offload).
    let offloaded = memory::breakdown(&spec, &scale, mesh.shard, tokens, A100_MEM_BYTES)
        .offloaded;
    let sm = StepModel {
        mesh,
        cost,
        param_bytes: (scale.params() * 4) as usize, // fp32 pseudo-grad state
        compute,
        cpu_offload: offloaded,
    };
    let sync_group = mesh.sync_group(0);
    let shard_bytes = sm.param_bytes / mesh.shard;
    let ar = cost.time(CollOp::AllReduce, shard_bytes, &sync_group);
    let exposed = sm.sync_exposed(&spec);

    let mut t = 0.0;
    let mut segments = Vec::new();
    let mut push = |name: &str, kind: SegKind, t: &mut f64, dur: f64| {
        if dur > 0.0 {
            segments.push(Segment { name: name.into(), kind, start: *t, dur });
            *t += dur;
        }
    };

    // Step τ's compute finishes, then the strategy's sync unfolds —
    // segment layout dispatches on the spec axes, so new descriptors
    // land in the right profile without a new match arm.
    push("step τ compute", SegKind::Compute, &mut t, compute);
    if !spec.is_local_sgd() {
        // Synchronous DDP: the gradient all-reduce runs every step.
        push("grad all-reduce (every step)", SegKind::ExposedComm, &mut t, ar * 0.45);
    } else if spec.layerwise() {
        // Layer-wise: module 0's sync is exposed; modules 1..L overlap
        // with the forward pass of the next round (prefetch).
        let mut t2 = t;
        push("module-0 sync + norms", SegKind::ExposedComm, &mut t, exposed);
        push("next-round fwd compute", SegKind::Compute, &mut t, compute);
        push(
            "layer-wise sync (prefetch-hidden)",
            SegKind::OverlappedComm,
            &mut t2,
            ar - exposed / 2.0,
        );
    } else if spec.outer_staleness > 0 {
        if spec.shard_outer_state {
            // CO2*: overlapped all-reduce + exposed shard handling.
            let mut t2 = t;
            push("shard gather (exposed)", SegKind::ExposedComm, &mut t, exposed / 2.0);
            push("shard scatter (exposed)", SegKind::ExposedComm, &mut t, exposed / 2.0);
            push("async all-reduce (hidden)", SegKind::OverlappedComm, &mut t2, ar);
        } else {
            // CO2: one-round-stale all-reduce rides the next compute.
            let mut t2 = t;
            push("next-round compute", SegKind::Compute, &mut t, compute);
            push("async all-reduce (hidden)", SegKind::OverlappedComm, &mut t2, ar);
        }
    } else if sm.cpu_offload {
        // DiLoCo with CPU-staged outer state.
        push("pseudo-grad all-reduce", SegKind::ExposedComm, &mut t, ar);
        push("CPU⇄GPU outer state", SegKind::CpuTransfer, &mut t, exposed - ar);
    } else {
        // Flat, fully exposed parameter exchange (Post Local SGD).
        push("param all-reduce (exposed)", SegKind::ExposedComm, &mut t, exposed);
    }
    Timeline { method, segments, exposed }
}

impl Timeline {
    /// Render as a fixed-width ASCII timeline (`width` chars spanning the
    /// longest segment end).
    pub fn render(&self, width: usize) -> String {
        let end = self
            .segments
            .iter()
            .map(|s| s.start + s.dur)
            .fold(0.0f64, f64::max)
            .max(1e-9);
        let mut out = format!(
            "{} (exposed sync delay: {:.1} ms)\n",
            self.method.name(),
            self.exposed * 1e3
        );
        for seg in &self.segments {
            let a = (seg.start / end * width as f64) as usize;
            let b = (((seg.start + seg.dur) / end * width as f64) as usize).max(a + 1);
            let mut line = vec![' '; width.max(b)];
            for c in line.iter_mut().take(b).skip(a) {
                *c = seg.kind.glyph();
            }
            out.push_str(&format!(
                "  |{}| {:<36} {:>9.1} ms\n",
                line.into_iter().collect::<String>(),
                seg.name,
                seg.dur * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_exposed_delays() {
        // Paper numbers: PLS ~160 ms, CO2* ~300 ms, EDiT ~19 ms, CO2 ~0.
        let pls = sync_timeline(Method::PostLocalSgd).exposed * 1e3;
        let co2 = sync_timeline(Method::Co2).exposed * 1e3;
        let co2s = sync_timeline(Method::Co2Star).exposed * 1e3;
        let edit = sync_timeline(Method::Edit).exposed * 1e3;
        assert!((80.0..320.0).contains(&pls), "PLS {pls} ms");
        assert!(co2 == 0.0);
        assert!((150.0..600.0).contains(&co2s), "CO2* {co2s} ms");
        assert!((5.0..60.0).contains(&edit), "EDiT {edit} ms");
        assert!(co2s > pls && pls > edit && edit > co2);
    }

    #[test]
    fn segments_nonnegative_and_named() {
        for m in Method::ALL {
            let tl = sync_timeline(m);
            assert!(!tl.segments.is_empty());
            for s in &tl.segments {
                assert!(s.dur >= 0.0 && s.start >= 0.0, "{m:?} {s:?}");
                assert!(!s.name.is_empty());
            }
        }
    }

    #[test]
    fn render_contains_all_segments() {
        let tl = sync_timeline(Method::Edit);
        let text = tl.render(60);
        for s in &tl.segments {
            assert!(text.contains(&s.name));
        }
        assert!(text.contains("ms"));
    }

    #[test]
    fn overlapped_marked_for_co2_and_edit() {
        for m in [Method::Co2, Method::Co2Star, Method::Edit] {
            let tl = sync_timeline(m);
            assert!(
                tl.segments.iter().any(|s| s.kind == SegKind::OverlappedComm),
                "{m:?}"
            );
        }
    }
}
