//! Analytic cluster performance simulator (DESIGN.md §2.6).
//!
//! The paper's throughput evaluation ran on 16–64 A100s; this box has
//! one CPU core.  Per the substitution rule, the module rebuilds that
//! evaluation analytically from first principles — a FLOPs/MFU compute
//! model ([`scales`]), a per-GPU memory model reproducing the OOM
//! pattern ([`memory`]), the shared α-β communication model
//! (`collectives::cost`), per-method step/sync timing ([`stepmodel`]),
//! scenario injection and end-to-end simulation ([`cluster`]), and the
//! Fig. 9 sync-timeline renderer ([`trace`]).

pub mod cluster;
pub mod memory;
pub mod scales;
pub mod stepmodel;
pub mod trace;

pub use cluster::{simulate, Scenario, SimConfig, SimResult};
pub use scales::ScaleSpec;
