//! Per-step and per-sync timing shared by the numerics trainer and the
//! analytic cluster simulator — the single source of truth for the
//! timing assumptions, priced from the [`MethodSpec`] strategy axes
//! (see `coordinator::spec`).
//!
//! Inner step (every method, FSDP/ZeRO-3 inside the shard group):
//!   fwd  all-gather(P·4 bytes)  + bwd all-gather + reduce-scatter,
//!   all intra-node for the paper layout; warmup/DDP adds the global
//!   gradient all-reduce across sync groups (inter-node).
//!
//! Sync step (every τ / τ_time): per-method profile, calibrated against
//! the paper's Fig. 9 profiling numbers for the Llama-1B run:
//!   Post Local SGD   ~160 ms  fully exposed parameter all-reduce
//!   DiLoCo           exposed all-reduce + CPU<->GPU staging when the
//!                    outer state is offloaded
//!   CO2              fully overlapped (0 exposed) but needs full extra
//!                    state in memory
//!   CO2*             overlapped all-reduce + 2 exposed shard-handling
//!                    segments (~300 ms)
//!   EDiT/A-EDiT      layer-wise sync overlapped with forward prefetch;
//!                    exposed residual ~= one layer's communication +
//!                    scalar norm exchanges (~19 ms)

use crate::collectives::{CollOp, CostModel};
use crate::coordinator::mesh::MeshSpec;
use crate::coordinator::spec::MethodSpec;

/// Fraction of a sync all-reduce EDiT cannot hide (first layer's comm
/// cannot overlap with anything).
const EDIT_EXPOSED_FRACTION: f64 = 0.08;
/// CO2* exposed shard-handling segments, expressed as a multiple of the
/// sync-group all-reduce time (two non-overlapped segments, Fig. 9).
const CO2STAR_EXPOSED_FACTOR: f64 = 1.9;
/// DiLoCo CPU-offload staging throughput (PCIe gen4 ~24 GB/s effective).
const PCIE_BW: f64 = 24e9;

#[derive(Debug, Clone)]
pub struct StepModel {
    pub mesh: MeshSpec,
    pub cost: CostModel,
    /// Bytes of one full parameter replica (P * 4).
    pub param_bytes: usize,
    /// Pure compute time of one inner step on one worker (seconds).
    pub compute: f64,
    /// Whether the outer state had to be offloaded to CPU (memory
    /// pressure — DiLoCo at 1B in the paper).
    pub cpu_offload: bool,
}

impl StepModel {
    /// Per-worker communication time of the FSDP inner step (fwd
    /// all-gather + bwd all-gather + reduce-scatter in the shard group).
    /// XLA overlaps these with compute; `overlap` is the hidden
    /// fraction (0.9 reflects the paper's profiler traces).
    pub fn fsdp_comm(&self) -> f64 {
        let group = self.mesh.shard_group(0);
        let ag = self.cost.time(CollOp::AllGather, self.param_bytes, &group);
        let rs = self.cost.time(CollOp::ReduceScatter, self.param_bytes, &group);
        2.0 * ag + rs
    }

    /// Exposed (non-hidden) time of one inner step, excluding compute.
    pub fn inner_step_exposed(&self, warmup_or_ddp: bool) -> f64 {
        let overlap = 0.9;
        let mut t = self.fsdp_comm() * (1.0 - overlap);
        if warmup_or_ddp {
            // Global gradient all-reduce across sync groups (inter-node),
            // exposed after the backward pass. Each worker all-reduces its
            // grad shard across its sync group.
            let group = self.mesh.sync_group(0);
            let shard_bytes = self.param_bytes / self.mesh.shard;
            t += self.cost.time(CollOp::AllReduce, shard_bytes, &group);
        }
        t
    }

    /// Total simulated duration of one inner step.
    pub fn inner_step(&self, warmup_or_ddp: bool) -> f64 {
        self.compute + self.inner_step_exposed(warmup_or_ddp)
    }

    /// Exposed synchronization time at an outer boundary for the
    /// strategy axes in `spec`. (The overlapped portion rides on top of
    /// the next round's compute.)
    pub fn sync_exposed(&self, spec: &MethodSpec) -> f64 {
        let group = self.mesh.sync_group(0);
        // Pseudo-gradient exchanges travel at the payload wire width
        // (spec.payload); for f32 this reduces to `param_bytes` exactly,
        // keeping the historical pricing bitwise. The warmup/DDP
        // gradient all-reduce (inner_step_exposed) always stays f32.
        let wire = spec.payload.wire_bytes(self.param_bytes / 4);
        let shard_bytes = wire / self.mesh.shard;
        let ar = self.cost.time(CollOp::AllReduce, shard_bytes, &group);
        if !spec.is_local_sgd() {
            // No periodic sync at all (pure DDP baseline).
            return 0.0;
        }
        if spec.layerwise() {
            // Layer-wise prefetch hides all but the first module, plus
            // the per-module scalar norm exchange (EDiT family).
            let scalar = self
                .cost
                .time(CollOp::ScalarSync, 4, &self.mesh.shard_group(0));
            return ar * EDIT_EXPOSED_FRACTION + scalar;
        }
        if spec.outer_staleness > 0 {
            // CO2-style overlap: the exchange hides behind the next
            // round; sharded outer state (CO2*) pays the exposed shard
            // gather/scatter segments instead.
            return if spec.shard_outer_state {
                ar * CO2STAR_EXPOSED_FACTOR
            } else {
                0.0
            };
        }
        // Flat, immediately-applied outer update: the all-reduce is
        // fully exposed (PLS/DiLoCo), plus PCIe staging when the outer
        // state lives on CPU (DiLoCo at 1B in the paper).
        let mut t = ar;
        if self.cpu_offload {
            // Stage full extra params + momentum over PCIe, exposed.
            t += 2.0 * (self.param_bytes as f64) / PCIE_BW;
        }
        t
    }

    /// Exposed residual of the layer-wise sync pipeline, given the
    /// per-module full-vector byte counts (the trainer passes the real
    /// `ModuleTable` layout; the analytic simulator can pass uniform
    /// layers).
    ///
    /// Model (paper §3.1): at a sync boundary the per-module shard
    /// all-reduces are issued in module order while the next round's
    /// forward pass consumes modules in the same order — module k's
    /// all-reduce hides behind the forward compute of the modules
    /// pipelined before it, so the exposed cost per module is the
    /// pipeline *stall* `max(0, comm_done_k − compute_done_{k-1})`
    /// rather than the full communication time. The first module can
    /// never hide (nothing computes before it); with zero compute the
    /// whole serial communication is exposed. One scalar-norm latency
    /// (shard group) rides on top: the per-module scalar exchanges are
    /// all charged to communication accounting, but they pipeline
    /// behind the module all-reduces, so only a single latency is
    /// modeled as exposed.
    pub fn layerwise_exposed(&self, module_bytes: &[usize]) -> f64 {
        self.layerwise_exposed_ops(module_bytes, false)
    }

    /// [`Self::layerwise_exposed`] with the per-module op decomposition
    /// made explicit: `sharded` prices each module as a reduce-scatter
    /// of the pseudo-gradients plus an all-gather of the updated anchor
    /// shards (the ZeRO-1 outer-sharding path) instead of one
    /// all-reduce. The ring α-β model decomposes exactly — the pair
    /// costs bitwise the same as the all-reduce (`collectives::cost`) —
    /// which is the paper's claim that sharding the outer state exposes
    /// no additional synchronization time.
    pub fn layerwise_exposed_ops(&self, module_bytes: &[usize], sharded: bool) -> f64 {
        let scalar = self
            .cost
            .time(CollOp::ScalarSync, 4, &self.mesh.shard_group(0));
        let total: usize = module_bytes.iter().sum();
        if module_bytes.is_empty() || total == 0 {
            return scalar;
        }
        let group = self.mesh.sync_group(0);
        let mut comm_end = 0.0f64; // completion time of module k's exchange
        let mut fwd_end = 0.0f64; // completion time of module k's forward
        let mut compute_total = 0.0f64;
        for &mb in module_bytes {
            let shard_b = (mb / self.mesh.shard).max(1);
            comm_end += if sharded {
                self.cost.time(CollOp::ReduceScatter, shard_b, &group)
                    + self.cost.time(CollOp::AllGather, shard_b, &group)
            } else {
                self.cost.time(CollOp::AllReduce, shard_b, &group)
            };
            let c = self.compute * mb as f64 / total as f64;
            let start = comm_end.max(fwd_end);
            fwd_end = start + c;
            compute_total += c;
        }
        (fwd_end - compute_total) + scalar
    }

    /// Average simulated seconds per inner step including the amortized
    /// sync cost at interval `tau`.
    pub fn amortized_step(&self, spec: &MethodSpec, tau: u64, warmup_or_ddp: bool) -> f64 {
        let sync = if spec.is_local_sgd() {
            self.sync_exposed(spec) / tau.max(1) as f64
        } else {
            0.0
        };
        self.inner_step(warmup_or_ddp) + sync
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{CostModel, Topology};
    use crate::coordinator::Method;

    fn model() -> StepModel {
        StepModel {
            mesh: MeshSpec::new(8, 8),
            cost: CostModel::new(Topology::a100()),
            param_bytes: 1_300_000_000 * 4, // ~1B params
            compute: 0.5,
            cpu_offload: false,
        }
    }

    #[test]
    fn baseline_slower_than_local_sgd() {
        let m = model();
        let ddp = m.amortized_step(&Method::Baseline.spec(), 1, true);
        let edit = m.amortized_step(&Method::Edit.spec(), 128, false);
        assert!(ddp > edit, "ddp {ddp} vs edit {edit}");
    }

    #[test]
    fn sync_cost_ordering_matches_fig9() {
        // PLS (exposed) > CO2* (two exposed segments relative to shard
        // all-reduce)... per Fig 9 CO2* ~300ms > PLS ~160ms > EDiT ~19ms > CO2 ~0.
        let m = model();
        let pls = m.sync_exposed(&Method::PostLocalSgd.spec());
        let co2s = m.sync_exposed(&Method::Co2Star.spec());
        let edit = m.sync_exposed(&Method::Edit.spec());
        let co2 = m.sync_exposed(&Method::Co2.spec());
        assert!(co2s > pls, "{co2s} {pls}");
        assert!(pls > edit);
        assert!(edit > co2);
        assert_eq!(co2, 0.0);
    }

    #[test]
    fn fig9_absolute_scale_plausible() {
        // Paper: PLS ~160ms, CO2* ~300ms, EDiT ~19ms on Llama 1B (8x8).
        let m = model();
        let pls = m.sync_exposed(&Method::PostLocalSgd.spec());
        let co2s = m.sync_exposed(&Method::Co2Star.spec());
        let edit = m.sync_exposed(&Method::Edit.spec());
        assert!((0.05..0.5).contains(&pls), "PLS {pls}");
        assert!((0.1..0.9).contains(&co2s), "CO2* {co2s}");
        assert!((0.004..0.08).contains(&edit), "EDiT {edit}");
    }

    #[test]
    fn layerwise_overlap_hides_mid_modules() {
        // 26 uniform modules (Llama-1B-ish): per-module comm is far
        // smaller than per-module compute, so everything after module 0
        // hides — exposed ≈ first module's all-reduce + scalar sync.
        let m = model();
        let modules = vec![m.param_bytes / 26; 26];
        let exposed = m.layerwise_exposed(&modules);
        let group = m.mesh.sync_group(0);
        let per_module: f64 =
            m.cost.time(CollOp::AllReduce, (m.param_bytes / 26) / m.mesh.shard, &group);
        let serial = 26.0 * per_module;
        assert!(exposed < 0.5 * serial, "exposed {exposed} vs serial {serial}");
        assert!(exposed >= per_module, "first module can never hide");
        // And it stays in the same regime as the legacy fraction model.
        let legacy = m.sync_exposed(&Method::Edit.spec());
        assert!(exposed < 10.0 * legacy && exposed * 10.0 > legacy,
            "pipeline {exposed} vs legacy {legacy}");
    }

    #[test]
    fn layerwise_zero_compute_fully_exposed() {
        let mut m = model();
        m.compute = 0.0;
        let modules = vec![m.param_bytes / 8; 8];
        let group = m.mesh.sync_group(0);
        let serial: f64 = modules
            .iter()
            .map(|&mb| m.cost.time(CollOp::AllReduce, mb / m.mesh.shard, &group))
            .sum();
        let scalar = m.cost.time(CollOp::ScalarSync, 4, &m.mesh.shard_group(0));
        let exposed = m.layerwise_exposed(&modules);
        assert!((exposed - (serial + scalar)).abs() < 1e-12, "{exposed} vs {serial}");
    }

    #[test]
    fn layerwise_empty_modules_is_scalar_only() {
        let m = model();
        let scalar = m.cost.time(CollOp::ScalarSync, 4, &m.mesh.shard_group(0));
        assert_eq!(m.layerwise_exposed(&[]), scalar);
    }

    #[test]
    fn layerwise_sharded_pricing_is_bitwise_allreduce() {
        // Reduce-scatter + all-gather per module must expose exactly the
        // all-reduce pipeline stall: outer sharding costs no extra
        // exposed communication.
        let m = model();
        for modules in [vec![m.param_bytes / 26; 26], vec![m.param_bytes / 8; 8]] {
            let ar = m.layerwise_exposed_ops(&modules, false);
            let rs_ag = m.layerwise_exposed_ops(&modules, true);
            assert_eq!(ar.to_bits(), rs_ag.to_bits());
        }
    }

    #[test]
    fn quantized_payload_shrinks_flat_sync_pricing() {
        // int8 payload carries ~1/3.8 the bytes of f32, so the exposed
        // flat all-reduce must shrink accordingly; f32 payload must
        // price bitwise like the historical param_bytes expression.
        let m = model();
        let f = Method::DiLoCo.spec();
        let mut q = f;
        q.payload = crate::tensor::PayloadKind::Int8;
        let tf = m.sync_exposed(&f);
        let tq = m.sync_exposed(&q);
        assert!(tq < tf, "int8 {tq} vs f32 {tf}");
        let group = m.mesh.sync_group(0);
        let legacy =
            m.cost.time(CollOp::AllReduce, m.param_bytes / m.mesh.shard, &group);
        assert_eq!(tf.to_bits(), legacy.to_bits());
    }

    #[test]
    fn diloco_offload_penalty() {
        let mut m = model();
        let base = m.sync_exposed(&Method::DiLoCo.spec());
        m.cpu_offload = true;
        assert!(m.sync_exposed(&Method::DiLoCo.spec()) > base + 0.1);
    }

    #[test]
    fn warmup_adds_allreduce() {
        let m = model();
        assert!(m.inner_step(true) > m.inner_step(false));
    }

    #[test]
    fn larger_tau_amortizes_better() {
        let m = model();
        let t16 = m.amortized_step(&Method::PostLocalSgd.spec(), 16, false);
        let t128 = m.amortized_step(&Method::PostLocalSgd.spec(), 128, false);
        assert!(t128 < t16);
    }
}
