//! Parsed form of `artifacts/<config>/manifest.json` (the export
//! contract written by `python/compile/aot.py`).

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

use crate::tensor::ModuleTable;
use crate::util::json::Json;

/// Model architecture + inner-optimizer constants baked at lowering time.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab_size: usize,
    pub num_layers: usize,
    pub hidden_size: usize,
    pub intermediate_size: usize,
    pub num_heads: usize,
    pub seq_len: usize,
    pub batch_size: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelInfo,
    pub total_params: usize,
    pub penalty_phi: f64,
    pub table: ModuleTable,
    /// program name -> HLO filename (train_step, grad_step, ...).
    pub programs: BTreeMap<String, String>,
    /// sync-group size -> penalty HLO filename.
    pub penalty_programs: BTreeMap<usize, String>,
    pub init_file: String,
    /// [batch, seq+1]
    pub token_shape: [usize; 2],
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest json")?;
        Self::from_json(&json)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let get_usize = |path: &[&str]| -> Result<usize> {
            json.at(path)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing {}", path.join(".")))
        };

        let model = ModelInfo {
            name: json
                .at(&["config", "name"])
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            vocab_size: get_usize(&["config", "vocab_size"])?,
            num_layers: get_usize(&["config", "num_layers"])?,
            hidden_size: get_usize(&["config", "hidden_size"])?,
            intermediate_size: get_usize(&["config", "intermediate_size"])?,
            num_heads: get_usize(&["config", "num_heads"])?,
            seq_len: get_usize(&["config", "seq_len"])?,
            batch_size: get_usize(&["config", "batch_size"])?,
        };

        let mut programs = BTreeMap::new();
        if let Some(obj) = json.at(&["programs"]).and_then(Json::as_obj) {
            for key in obj.keys() {
                if let Some(file) = obj.get(key).and_then(Json::as_str) {
                    programs.insert(key.clone(), file.to_string());
                }
            }
        }
        anyhow::ensure!(!programs.is_empty(), "manifest has no programs");

        let mut penalty_programs = BTreeMap::new();
        if let Some(obj) = json.at(&["penalty_programs"]).and_then(Json::as_obj) {
            for key in obj.keys() {
                if let (Ok(n), Some(file)) =
                    (key.parse::<usize>(), obj.get(key).and_then(Json::as_str))
                {
                    penalty_programs.insert(n, file.to_string());
                }
            }
        }

        let token_shape = json
            .at(&["token_shape"])
            .and_then(Json::as_arr)
            .and_then(|a| {
                Some([a.first()?.as_usize()?, a.get(1)?.as_usize()?])
            })
            .ok_or_else(|| anyhow::anyhow!("manifest missing token_shape"))?;

        Ok(Self {
            model,
            total_params: get_usize(&["total_params"])?,
            penalty_phi: json
                .at(&["penalty_phi"])
                .and_then(Json::as_f64)
                .unwrap_or(10.0),
            table: ModuleTable::from_manifest(json)?,
            programs,
            penalty_programs,
            init_file: json
                .at(&["init_file"])
                .and_then(Json::as_str)
                .unwrap_or("init.bin")
                .to_string(),
            token_shape,
        })
    }

    /// Tokens per inner step per worker (B x S predicted positions).
    pub fn tokens_per_step(&self) -> usize {
        self.model.batch_size * self.model.seq_len
    }

    /// Build an in-memory manifest with the canonical tensor layout
    /// (embed + stacked per-layer block + head) — no artifacts on disk.
    ///
    /// Used by the stub runtime (`runtime::stub::Engine::synthetic`),
    /// benches and tests to drive full coordinator rounds at arbitrary
    /// parameter counts on a clean box. `layer_params` is the per-layer
    /// element count of the stacked block; `tail_params` is split
    /// between the unstacked embed/head tensors.
    /// The canonical clean-box stub-model shape shared by every
    /// artifacts-absent fallback (the `train` CLI, the experiment
    /// harnesses, the fig5 cross-validation) — one definition so the
    /// fallbacks can never drift apart in model shape.
    pub fn synthetic_fallback(name: &str) -> Manifest {
        Manifest::synthetic(name, 4, 256, 128, 64, 2, 8)
    }

    pub fn synthetic(
        name: &str,
        num_layers: usize,
        layer_params: usize,
        tail_params: usize,
        vocab: usize,
        batch: usize,
        seq_len: usize,
    ) -> Manifest {
        let embed = tail_params / 2;
        let head = tail_params - embed;
        let stacked = num_layers * layer_params;
        let tensors = vec![
            crate::tensor::TensorEntry {
                name: "embed".into(),
                shape: vec![embed],
                offset: 0,
                size: embed,
                stacked: false,
            },
            crate::tensor::TensorEntry {
                name: "layers.block".into(),
                shape: vec![num_layers, layer_params],
                offset: embed,
                size: stacked,
                stacked: true,
            },
            crate::tensor::TensorEntry {
                name: "head".into(),
                shape: vec![head],
                offset: embed + stacked,
                size: head,
                stacked: false,
            },
        ];
        let mut programs = BTreeMap::new();
        programs.insert("train_step".to_string(), "<synthetic>".to_string());
        programs.insert("grad_step".to_string(), "<synthetic>".to_string());
        programs.insert("apply_step".to_string(), "<synthetic>".to_string());
        programs.insert("eval_step".to_string(), "<synthetic>".to_string());
        Manifest {
            model: ModelInfo {
                name: name.to_string(),
                vocab_size: vocab,
                num_layers,
                hidden_size: layer_params.max(1),
                intermediate_size: layer_params.max(1),
                num_heads: 1,
                seq_len,
                batch_size: batch,
            },
            total_params: embed + stacked + head,
            penalty_phi: 10.0,
            table: ModuleTable::new(tensors, num_layers),
            programs,
            penalty_programs: BTreeMap::new(),
            init_file: "init.bin".to_string(),
            token_shape: [batch, seq_len + 1],
        }
    }
}

// ---------------------------------------------------------------------------
// Run-state checkpoint manifest (fault tolerance / elastic restarts)
// ---------------------------------------------------------------------------

/// Version of the on-disk run-state checkpoint format. Bump on any
/// layout change; `RunManifest::from_json` rejects mismatches loudly
/// instead of misreading old files.
///
/// History: 1 — initial format; 2 — `sync_residuals` F32 section (the
/// quantized payload axis's error-feedback buffers) after
/// `outer_momentum`, count 0 for `payload=f32`.
pub const RUN_STATE_VERSION: u32 = 2;

/// Magic prefix of a run-state checkpoint file.
pub const RUN_STATE_MAGIC: &[u8; 8] = b"EDITCKPT";

/// Element type of one checkpoint body section. Everything is encoded
/// little-endian; integers live in typed binary sections rather than
/// the JSON header because `Json::Num` is an f64 and would silently
/// lose precision past 2^53.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SectionKind {
    F32,
    F64,
    U64,
    I64,
    U8,
}

impl SectionKind {
    pub fn elem_bytes(self) -> usize {
        match self {
            SectionKind::F32 => 4,
            SectionKind::F64 | SectionKind::U64 | SectionKind::I64 => 8,
            SectionKind::U8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SectionKind::F32 => "f32",
            SectionKind::F64 => "f64",
            SectionKind::U64 => "u64",
            SectionKind::I64 => "i64",
            SectionKind::U8 => "u8",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "f32" => SectionKind::F32,
            "f64" => SectionKind::F64,
            "u64" => SectionKind::U64,
            "i64" => SectionKind::I64,
            "u8" => SectionKind::U8,
            _ => return None,
        })
    }
}

/// One named, typed, fixed-length section of the checkpoint body.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSection {
    pub name: String,
    pub kind: SectionKind,
    pub count: usize,
}

/// The versioned JSON header of a run-state checkpoint: identity checks
/// (seed, shapes) plus the self-describing section table of the binary
/// body that follows it. The writer/reader live in
/// `coordinator::engine::checkpoint`; this type owns only the format.
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    pub version: u32,
    pub label: String,
    /// Written as a decimal string — a u64 seed does not fit `Json::Num`.
    pub seed: u64,
    pub replicas: usize,
    pub params: usize,
    pub modules: usize,
    pub sections: Vec<RunSection>,
}

impl RunManifest {
    pub fn to_json(&self) -> Json {
        let mut obj = crate::util::json::Obj::new();
        obj.insert("version", self.version as usize);
        obj.insert("label", self.label.as_str());
        obj.insert("seed", format!("{}", self.seed));
        obj.insert("replicas", self.replicas);
        obj.insert("params", self.params);
        obj.insert("modules", self.modules);
        let sections: Vec<Json> = self
            .sections
            .iter()
            .map(|s| {
                let mut o = crate::util::json::Obj::new();
                o.insert("name", s.name.as_str());
                o.insert("kind", s.kind.name());
                o.insert("count", s.count);
                Json::Obj(o)
            })
            .collect();
        obj.insert("sections", Json::Arr(sections));
        Json::Obj(obj)
    }

    pub fn from_json(json: &Json) -> Result<Self> {
        let version = json
            .at(&["version"])
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("run manifest missing version"))?
            as u32;
        anyhow::ensure!(
            version == RUN_STATE_VERSION,
            "run-state checkpoint version {version} != supported {RUN_STATE_VERSION}"
        );
        let get = |key: &str| -> Result<usize> {
            json.at(&[key])
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("run manifest missing {key}"))
        };
        let seed: u64 = json
            .at(&["seed"])
            .and_then(Json::as_str)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("run manifest missing seed"))?;
        let mut sections = Vec::new();
        for s in json
            .at(&["sections"])
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("run manifest missing sections"))?
        {
            let name = s
                .at(&["name"])
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("section missing name"))?
                .to_string();
            let kind = s
                .at(&["kind"])
                .and_then(Json::as_str)
                .and_then(SectionKind::parse)
                .ok_or_else(|| anyhow::anyhow!("section '{name}' has a bad kind"))?;
            let count = s
                .at(&["count"])
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("section '{name}' missing count"))?;
            sections.push(RunSection { name, kind, count });
        }
        Ok(Self {
            version,
            label: json
                .at(&["label"])
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            seed,
            replicas: get("replicas")?,
            params: get("params")?,
            modules: get("modules")?,
            sections,
        })
    }

    /// Total byte length of the binary body the section table describes.
    pub fn body_bytes(&self) -> usize {
        self.sections.iter().map(|s| s.count * s.kind.elem_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
 "config": {"name": "test", "vocab_size": 256, "num_layers": 2,
            "hidden_size": 32, "intermediate_size": 96, "num_heads": 2,
            "seq_len": 32, "batch_size": 2},
 "total_params": 10,
 "penalty_phi": 10.0,
 "tensors": [
   {"name": "embed", "shape": [5], "offset": 0, "size": 5, "stacked": false},
   {"name": "layers.w", "shape": [2, 2], "offset": 5, "size": 4, "stacked": true},
   {"name": "head", "shape": [1], "offset": 9, "size": 1, "stacked": false}
 ],
 "programs": {"train_step": "train_step.hlo.txt", "eval_step": "eval_step.hlo.txt"},
 "penalty_programs": {"2": "penalty_w2.hlo.txt", "4": "penalty_w4.hlo.txt"},
 "init_file": "init.bin",
 "token_shape": [2, 33]
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.model.name, "test");
        assert_eq!(m.total_params, 10);
        assert_eq!(m.programs["train_step"], "train_step.hlo.txt");
        assert_eq!(m.penalty_programs[&4], "penalty_w4.hlo.txt");
        assert_eq!(m.token_shape, [2, 33]);
        assert_eq!(m.tokens_per_step(), 64);
        assert_eq!(m.table.num_modules(), 3);
    }

    #[test]
    fn rejects_empty_programs() {
        let j = Json::parse(
            r#"{"config": {"name": "x", "vocab_size": 1, "num_layers": 1,
                "hidden_size": 1, "intermediate_size": 1, "num_heads": 1,
                "seq_len": 1, "batch_size": 1},
               "total_params": 0, "tensors": [], "programs": {},
               "token_shape": [1, 2]}"#,
        )
        .unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn synthetic_manifest_is_consistent() {
        let m = Manifest::synthetic("syn", 3, 100, 31, 64, 2, 16);
        assert_eq!(m.total_params, 3 * 100 + 31);
        assert_eq!(m.table.total, m.total_params);
        assert_eq!(m.table.num_modules(), 4);
        assert_eq!(m.token_shape, [2, 17]);
        // Modules partition the flat vector exactly.
        let mut covered = vec![false; m.total_params];
        for module in 0..m.table.num_modules() {
            for r in m.table.module_ranges(module) {
                for i in r.offset..r.offset + r.len {
                    assert!(!covered[i], "overlap at {i}");
                    covered[i] = true;
                }
            }
        }
        assert!(covered.iter().all(|&c| c));
    }

    #[test]
    fn run_manifest_roundtrips_through_json() {
        let m = RunManifest {
            version: RUN_STATE_VERSION,
            label: "edit".to_string(),
            // Past 2^53 — would corrupt if stored as a JSON number.
            seed: u64::MAX - 7,
            replicas: 4,
            params: 331,
            modules: 4,
            sections: vec![
                RunSection { name: "anchor".into(), kind: SectionKind::F32, count: 331 },
                RunSection { name: "counters".into(), kind: SectionKind::U64, count: 19 },
                RunSection { name: "alive".into(), kind: SectionKind::U8, count: 4 },
            ],
        };
        let text = m.to_json().to_string();
        let back = RunManifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.body_bytes(), 331 * 4 + 19 * 8 + 4);
    }

    #[test]
    fn run_manifest_rejects_bad_versions() {
        let mut m = RunManifest {
            version: RUN_STATE_VERSION,
            label: "x".into(),
            seed: 1,
            replicas: 1,
            params: 1,
            modules: 1,
            sections: Vec::new(),
        };
        m.version = RUN_STATE_VERSION + 1;
        let text = m.to_json().to_string();
        assert!(RunManifest::from_json(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn section_kind_names_roundtrip() {
        for kind in [
            SectionKind::F32,
            SectionKind::F64,
            SectionKind::U64,
            SectionKind::I64,
            SectionKind::U8,
        ] {
            assert_eq!(SectionKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SectionKind::parse("f16"), None);
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/test/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert_eq!(m.model.name, "test");
            assert!(m.total_params > 0);
            assert!(m.programs.contains_key("train_step"));
        }
    }
}
