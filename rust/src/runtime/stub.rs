//! Deterministic pure-Rust execution backend (the default build,
//! `pjrt` feature off).
//!
//! The stub realizes the same `Engine` API as the PJRT backend over a
//! synthetic differentiable objective instead of the compiled HLO
//! model: a quadratic pull toward a seed-derived target vector plus a
//! per-batch pseudo-noise term, optimized by a faithful AdamW. That is
//! enough for everything above Layer 2 to run for real — losses start
//! near ln(V) and decrease, replicas on different data streams diverge
//! (so pseudo-gradients, the penalty pipeline and sync rounds are all
//! non-trivial) — while keeping the default build free of external
//! native dependencies.
//!
//! Determinism: every number is a pure function of (manifest name,
//! params, tokens), so reruns are bit-identical, matching the
//! coordinator's reproducibility contract.
//!
//! Hot-path discipline: `train_step`/`grad_step`/`apply_step`/`eval_step`
//! allocate nothing — single fused sweeps over the flat vectors — which
//! is what lets `tests/sync_steady_state.rs` assert the trainer-level
//! zero-allocation invariant over full rounds.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::util::prng::{mix, Rng};

use super::{Manifest, StepOut};

const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const EPS: f32 = 1e-8;
/// Relative amplitude of the per-batch pseudo-noise on the gradient.
const NOISE: f32 = 0.2;
/// Parameter init / target scale.
const SCALE: f32 = 0.05;

/// Deterministic stand-in for the PJRT engine (same API surface).
pub struct Engine {
    pub manifest: Manifest,
    dir: Option<PathBuf>,
    seed: u64,
    /// The objective's optimum: loss ∝ mean((params - target)²).
    target: Vec<f32>,
    /// ln(vocab) / mean((init - target)²): scales the quadratic so the
    /// initial loss sits at ln(V) like a real LM at init.
    loss_scale: f64,
}

fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn hash_tokens(tokens: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        h ^= t as u32 as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Engine {
    /// Load the manifest for `config` under `artifacts_root`. Uses
    /// `init.bin` when present; otherwise parameters are generated
    /// deterministically from the config name.
    pub fn load(artifacts_root: impl AsRef<Path>, config: &str) -> Result<Self> {
        let dir = artifacts_root.as_ref().join(config);
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for config '{config}'"))?;
        Ok(Self::from_manifest(manifest, Some(dir)))
    }

    /// Build an engine over an in-memory manifest — no artifacts needed.
    /// This is how benches and tests drive full coordinator rounds on a
    /// clean box (see [`Manifest::synthetic`]).
    pub fn synthetic(manifest: Manifest) -> Self {
        Self::from_manifest(manifest, None)
    }

    fn from_manifest(manifest: Manifest, dir: Option<PathBuf>) -> Self {
        let seed = hash_str(&manifest.model.name);
        let p = manifest.total_params;
        let mut rng = Rng::new(mix(seed, 0x7A46_E7));
        let target: Vec<f32> = (0..p).map(|_| (rng.f64() as f32 * 2.0 - 1.0) * SCALE).collect();
        let mut engine =
            Self { manifest, dir, seed, target, loss_scale: 1.0 };
        // Calibrate so loss(init) == ln(vocab).
        let init = engine.generated_init();
        let d2 = engine.mean_sq_dist(&init);
        let lnv = (engine.manifest.model.vocab_size.max(2) as f64).ln();
        engine.loss_scale = lnv / d2.max(1e-12);
        engine
    }

    fn generated_init(&self) -> Vec<f32> {
        let mut rng = Rng::new(mix(self.seed, 0x1817_11));
        (0..self.manifest.total_params)
            .map(|_| (rng.f64() as f32 * 2.0 - 1.0) * SCALE)
            .collect()
    }

    fn mean_sq_dist(&self, params: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for (&p, &t) in params.iter().zip(&self.target) {
            let e = (p - t) as f64;
            acc += e * e;
        }
        acc / params.len().max(1) as f64
    }

    pub fn platform(&self) -> String {
        "stub-cpu (pjrt feature disabled)".to_string()
    }

    /// Initial flat parameters: `init.bin` when artifacts exist, else
    /// the deterministic generated init.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        if let Some(dir) = &self.dir {
            let path = dir.join(&self.manifest.init_file);
            if path.exists() {
                return super::read_init_bin(&path, self.manifest.total_params);
            }
        }
        Ok(self.generated_init())
    }

    /// No executables to compile — a no-op kept for API parity.
    pub fn warmup(&mut self) -> Result<()> {
        Ok(())
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        let [b, s1] = self.manifest.token_shape;
        anyhow::ensure!(
            tokens.len() == b * s1,
            "tokens len {} != {}x{}",
            tokens.len(),
            b,
            s1
        );
        Ok(())
    }

    /// Per-batch pseudo-noise stream: the gradient is
    /// g_i = (θ_i − t_i)·(1 + ε_i) with ε drawn from this rng, so the
    /// step functions stream g_i without materializing a buffer.
    fn batch_rng(&self, tokens: &[i32]) -> Rng {
        Rng::new(mix(self.seed ^ 0x6E01_5E, hash_tokens(tokens)))
    }

    fn loss_of(&self, params: &[f32]) -> f32 {
        (self.mean_sq_dist(params) * self.loss_scale) as f32
    }

    /// Fused inner step: params/m/v updated in place, returns the loss.
    /// Exactly equivalent to `grad_step` followed by `apply_step`.
    ///
    /// `&self` receiver on purpose: the stub holds no mutable state, so
    /// the trainer's parallel worker lanes can share one engine across
    /// threads (see `coordinator::engine::worker`). The PJRT backend
    /// keeps `&mut self` (executable cache) and is single-threaded.
    pub fn train_step(
        &self,
        params: &mut Vec<f32>,
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        tokens: &[i32],
        lr: f32,
        step: i32,
    ) -> Result<StepOut> {
        self.check_tokens(tokens)?;
        let loss = self.loss_of(params);
        let mut rng = self.batch_rng(tokens);
        let bc1 = 1.0 - BETA1.powi(step);
        let bc2 = 1.0 - BETA2.powi(step);
        for ((p, mi), (vi, &t)) in params
            .iter_mut()
            .zip(m.iter_mut())
            .zip(v.iter_mut().zip(&self.target))
        {
            let g = (*p - t) * (1.0 + NOISE * rng.normal_f32());
            *mi = BETA1 * *mi + (1.0 - BETA1) * g;
            *vi = BETA2 * *vi + (1.0 - BETA2) * g * g;
            let mhat = *mi / bc1;
            let vhat = *vi / bc2;
            *p -= lr * mhat / (vhat.sqrt() + EPS);
        }
        Ok(StepOut { loss })
    }

    /// Grads + loss without applying (DDP / warmup path).
    pub fn grad_step(
        &self,
        params: &[f32],
        tokens: &[i32],
        grads: &mut Vec<f32>,
    ) -> Result<StepOut> {
        self.check_tokens(tokens)?;
        let loss = self.loss_of(params);
        let mut rng = self.batch_rng(tokens);
        grads.resize(params.len(), 0.0);
        for ((g, &p), &t) in grads.iter_mut().zip(params).zip(&self.target) {
            *g = (p - t) * (1.0 + NOISE * rng.normal_f32());
        }
        Ok(StepOut { loss })
    }

    /// AdamW apply of externally averaged grads.
    pub fn apply_step(
        &self,
        params: &mut Vec<f32>,
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        grads: &[f32],
        lr: f32,
        step: i32,
    ) -> Result<()> {
        anyhow::ensure!(grads.len() == params.len(), "grads len mismatch");
        let bc1 = 1.0 - BETA1.powi(step);
        let bc2 = 1.0 - BETA2.powi(step);
        for ((p, mi), (vi, &g)) in params
            .iter_mut()
            .zip(m.iter_mut())
            .zip(v.iter_mut().zip(grads))
        {
            *mi = BETA1 * *mi + (1.0 - BETA1) * g;
            *vi = BETA2 * *vi + (1.0 - BETA2) * g * g;
            let mhat = *mi / bc1;
            let vhat = *vi / bc2;
            *p -= lr * mhat / (vhat.sqrt() + EPS);
        }
        Ok(())
    }

    /// Validation loss on one batch (pure function of params).
    pub fn eval_step(&self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        self.check_tokens(tokens)?;
        Ok(self.loss_of(params))
    }

    /// The stub cannot execute penalty HLO variants, even when the
    /// manifest lists them.
    pub fn has_penalty_program(&self, _n: usize) -> bool {
        false
    }

    /// The AOT Pallas penalty combine needs the PJRT backend.
    pub fn penalty_combine(&self, _deltas: &[&[f32]], _norms: &[f32]) -> Result<Vec<f32>> {
        anyhow::bail!(
            "penalty_combine requires the AOT penalty HLO (build with --features pjrt)"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::synthetic(Manifest::synthetic("stub-test", 2, 64, 32, 128, 2, 8))
    }

    fn batch(e: &Engine, salt: i32) -> Vec<i32> {
        let [b, s1] = e.manifest.token_shape;
        (0..b * s1).map(|i| (i as i32 * 7 + salt) % 128).collect()
    }

    #[test]
    fn deterministic_and_learns() {
        let e1 = engine();
        let e2 = engine();
        let mut p1 = e1.init_params().unwrap();
        let mut p2 = e2.init_params().unwrap();
        assert_eq!(p1, p2);
        let n = p1.len();
        let (mut m1, mut v1) = (vec![0.0; n], vec![0.0; n]);
        let (mut m2, mut v2) = (vec![0.0; n], vec![0.0; n]);
        let tokens = batch(&e1, 0);
        let first = e1.eval_step(&p1, &tokens).unwrap();
        let lnv = (e1.manifest.model.vocab_size as f32).ln();
        assert!((first - lnv).abs() < 1e-3, "init loss {first} vs ln(V) {lnv}");
        let mut last = first;
        for step in 1..=50 {
            let o1 = e1.train_step(&mut p1, &mut m1, &mut v1, &tokens, 5e-3, step).unwrap();
            let o2 = e2.train_step(&mut p2, &mut m2, &mut v2, &tokens, 5e-3, step).unwrap();
            assert_eq!(o1.loss, o2.loss, "determinism at step {step}");
            last = o1.loss;
        }
        assert_eq!(p1, p2);
        assert!(last < first * 0.5, "loss should halve: {first} -> {last}");
    }

    #[test]
    fn fused_equals_split_path() {
        let e = engine();
        let p0 = e.init_params().unwrap();
        let n = p0.len();
        let tokens = batch(&e, 3);

        let mut p1 = p0.clone();
        let (mut m1, mut v1) = (vec![0.0; n], vec![0.0; n]);
        let o1 = e.train_step(&mut p1, &mut m1, &mut v1, &tokens, 1e-3, 1).unwrap();

        let mut grads = vec![0.0; n];
        let o2 = e.grad_step(&p0, &tokens, &mut grads).unwrap();
        let mut p2 = p0.clone();
        let (mut m2, mut v2) = (vec![0.0; n], vec![0.0; n]);
        e.apply_step(&mut p2, &mut m2, &mut v2, &grads, 1e-3, 1).unwrap();

        assert_eq!(o1.loss, o2.loss);
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn different_batches_diverge() {
        let e = engine();
        let p0 = e.init_params().unwrap();
        let n = p0.len();
        let (mut pa, mut pb) = (p0.clone(), p0);
        let (mut ma, mut va) = (vec![0.0; n], vec![0.0; n]);
        let (mut mb, mut vb) = (vec![0.0; n], vec![0.0; n]);
        let ta = batch(&e, 1);
        let tb = batch(&e, 2);
        e.train_step(&mut pa, &mut ma, &mut va, &ta, 1e-3, 1).unwrap();
        e.train_step(&mut pb, &mut mb, &mut vb, &tb, 1e-3, 1).unwrap();
        assert_ne!(pa, pb, "distinct data streams must diverge");
    }

    #[test]
    fn rejects_bad_token_shape() {
        let e = engine();
        let p = e.init_params().unwrap();
        assert!(e.eval_step(&p, &[1, 2, 3]).is_err());
    }
}
