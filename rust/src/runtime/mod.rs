//! Layer-3 ⇄ Layer-2 runtime: load AOT artifacts and execute them
//! (DESIGN.md §2.3).
//!
//! `make artifacts` (python, build-time only) writes
//! `artifacts/<config>/{*.hlo.txt, manifest.json, init.bin}`; this module
//! is everything the Rust hot loop needs to run them:
//!
//!  * [`manifest::Manifest`] — the parsed export contract;
//!  * [`Engine`]             — the execution backend.
//!
//! Two backends share the same `Engine` API surface, selected at
//! compile time by the `pjrt` cargo feature:
//!
//!  * **`pjrt` enabled** ([`pjrt`] module): the real thing — compiled
//!    HLO executables on the PJRT CPU client via the vendored `xla`
//!    crate. Requires that crate (see `Cargo.toml`).
//!  * **default** ([`stub`] module): a deterministic pure-Rust stand-in
//!    (quadratic pseudo-model + real AdamW) with zero external
//!    dependencies, so `cargo build && cargo test` work on a clean box
//!    and the coordinator / bench layers can exercise full training
//!    rounds — including via [`stub::Engine::synthetic`] manifests —
//!    without any artifacts.
//!
//! Receiver divergence (since the event-driven trainer): the stub's
//! step methods (`train_step`/`grad_step`/`apply_step`/`eval_step`)
//! take `&self` so the trainer's parallel worker lanes and the
//! synthetic experiment harnesses can share one engine across threads.
//! The PJRT backend keeps `&mut self` (its executable cache mutates on
//! first use) and is single-threaded, so the lane path and the
//! synthetic-fallback harnesses do not compile under `--features pjrt`
//! as-is. Whoever wires the vendored `xla` crate in (the feature
//! already requires that manual step — see `Cargo.toml`) should either
//! pre-compile the executables and move the cache behind interior
//! mutability to adopt `&self`, or pin `worker_threads = 1` and gate
//! the lane path. Until then the divergence is latent: the `pjrt`
//! feature cannot build without the vendored crate anyway.

pub mod manifest;

pub use manifest::Manifest;

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;

/// Output of one fused inner training step.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    pub loss: f32,
}

/// Read an `init.bin` flat-f32 export, validating its size — shared by
/// both backends so the format can only evolve in one place.
pub(crate) fn read_init_bin(
    path: &std::path::Path,
    total_params: usize,
) -> anyhow::Result<Vec<f32>> {
    use anyhow::Context;
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(
        bytes.len() == total_params * 4,
        "init.bin size {} != 4 * total_params {}",
        bytes.len(),
        total_params
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
