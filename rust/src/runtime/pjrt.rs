//! PJRT execution backend (`--features pjrt`): compile the AOT HLO
//! programs and run them on the PJRT CPU client.
//!
//! Marshalling notes: parameters travel as rank-1 f32 literals (the flat
//! vector contract), tokens as an i32 `[batch, seq+1]` literal. Literals
//! are rebuilt per call from reusable host buffers; PJRT copies
//! host→device on execute, so the worker state of record stays in plain
//! `Vec<f32>` where the coordinator's outer algebra operates.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// E0432 "can't find crate for `xla`" on the next line means the `pjrt`
// feature was enabled without wiring the vendored xla-rs crate. See the
// header of rust/Cargo.toml: add the optional `xla` dependency and
// extend the feature to `pjrt = ["dep:xla"]` before building with
// `--features pjrt`. Default (stub-runtime) builds never compile this
// file.
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{Manifest, StepOut};

/// Compiled-program cache over one PJRT CPU client.
pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, PjRtLoadedExecutable>,
}

impl Engine {
    /// Load the manifest for `config` under `artifacts_root` and set up the
    /// PJRT CPU client. Executables compile lazily on first use.
    pub fn load(artifacts_root: impl AsRef<Path>, config: &str) -> Result<Self> {
        let dir = artifacts_root.as_ref().join(config);
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for config '{config}'"))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, dir, manifest, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Initial flat parameters exported by aot.py (`init.bin`).
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.manifest.init_file);
        super::read_init_bin(&path, self.manifest.total_params)
    }

    fn executable(&mut self, file: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.executables.contains_key(file) {
            let path = self.dir.join(file);
            let proto = HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            self.executables.insert(file.to_string(), exe);
        }
        Ok(&self.executables[file])
    }

    fn program_file(&self, name: &str) -> Result<String> {
        self.manifest
            .programs
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("program '{name}' not in manifest"))
    }

    /// Eagerly compile every model program (excludes penalty variants).
    pub fn warmup(&mut self) -> Result<()> {
        for name in ["train_step", "grad_step", "apply_step", "eval_step"] {
            let file = self.program_file(name)?;
            self.executable(&file)?;
        }
        Ok(())
    }

    fn tokens_literal(&self, tokens: &[i32]) -> Result<Literal> {
        let [b, s1] = self.manifest.token_shape;
        anyhow::ensure!(
            tokens.len() == b * s1,
            "tokens len {} != {}x{}",
            tokens.len(),
            b,
            s1
        );
        Ok(Literal::vec1(tokens).reshape(&[b as i64, s1 as i64])?)
    }

    fn run(&mut self, file: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(file)?;
        let result = exe.execute::<Literal>(args)?;
        let out = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("executable returned no buffers"))?
            .to_literal_sync()?;
        Ok(out.to_tuple()?)
    }

    /// Fused inner step: params/m/v updated in place, returns the loss.
    pub fn train_step(
        &mut self,
        params: &mut Vec<f32>,
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        tokens: &[i32],
        lr: f32,
        step: i32,
    ) -> Result<StepOut> {
        let file = self.program_file("train_step")?;
        let args = [
            Literal::vec1(params),
            Literal::vec1(m),
            Literal::vec1(v),
            self.tokens_literal(tokens)?,
            Literal::scalar(lr),
            Literal::scalar(step),
        ];
        let outs = self.run(&file, &args)?;
        anyhow::ensure!(outs.len() == 4, "train_step returned {}", outs.len());
        copy_into(&outs[0], params)?;
        copy_into(&outs[1], m)?;
        copy_into(&outs[2], v)?;
        Ok(StepOut { loss: outs[3].to_vec::<f32>()?[0] })
    }

    /// Grads + loss without applying (DDP / warmup path).
    pub fn grad_step(
        &mut self,
        params: &[f32],
        tokens: &[i32],
        grads: &mut Vec<f32>,
    ) -> Result<StepOut> {
        let file = self.program_file("grad_step")?;
        let args = [Literal::vec1(params), self.tokens_literal(tokens)?];
        let outs = self.run(&file, &args)?;
        anyhow::ensure!(outs.len() == 2, "grad_step returned {}", outs.len());
        copy_into(&outs[0], grads)?;
        Ok(StepOut { loss: outs[1].to_vec::<f32>()?[0] })
    }

    /// AdamW apply of externally averaged grads.
    pub fn apply_step(
        &mut self,
        params: &mut Vec<f32>,
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        grads: &[f32],
        lr: f32,
        step: i32,
    ) -> Result<()> {
        let file = self.program_file("apply_step")?;
        let args = [
            Literal::vec1(params),
            Literal::vec1(m),
            Literal::vec1(v),
            Literal::vec1(grads),
            Literal::scalar(lr),
            Literal::scalar(step),
        ];
        let outs = self.run(&file, &args)?;
        anyhow::ensure!(outs.len() == 3, "apply_step returned {}", outs.len());
        copy_into(&outs[0], params)?;
        copy_into(&outs[1], m)?;
        copy_into(&outs[2], v)?;
        Ok(())
    }

    /// Validation loss on one batch.
    pub fn eval_step(&mut self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        let file = self.program_file("eval_step")?;
        let args = [Literal::vec1(params), self.tokens_literal(tokens)?];
        let outs = self.run(&file, &args)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }

    /// Whether a penalty HLO exists for sync groups of `n` workers.
    pub fn has_penalty_program(&self, n: usize) -> bool {
        self.manifest.penalty_programs.contains_key(&n)
    }

    /// Execute the AOT penalty combine (Alg. 2, L1 Pallas kernel) for a
    /// group of `deltas.len()` workers. `norms` may contain +inf for
    /// anomaly-eliminated workers. Returns the combined clipped pseudo
    /// gradient (shared by all workers in the group).
    pub fn penalty_combine(
        &mut self,
        deltas: &[&[f32]],
        norms: &[f32],
    ) -> Result<Vec<f32>> {
        let n = deltas.len();
        anyhow::ensure!(n == norms.len());
        let file = self
            .manifest
            .penalty_programs
            .get(&n)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no penalty program for n={n}"))?;
        let p = self.manifest.total_params;
        let mut stacked = Vec::with_capacity(n * p);
        for d in deltas {
            anyhow::ensure!(d.len() == p, "delta len {} != {}", d.len(), p);
            stacked.extend_from_slice(d);
        }
        let args = [
            Literal::vec1(&stacked).reshape(&[n as i64, p as i64])?,
            Literal::vec1(norms),
        ];
        let outs = self.run(&file, &args)?;
        anyhow::ensure!(outs.len() == 3, "penalty returned {}", outs.len());
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// Copy a rank-1 f32 literal into an existing Vec without reallocating.
fn copy_into(lit: &Literal, dst: &mut Vec<f32>) -> Result<()> {
    let n = lit.element_count();
    dst.resize(n, 0.0);
    lit.copy_raw_to(dst.as_mut_slice())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    // Engine integration tests live in rust/tests/runtime_integration.rs
    // (they need built artifacts). Here: pure helpers only.

    #[test]
    fn copy_into_resizes() {
        let lit = xla::Literal::vec1(&[1.0f32, 2.0, 3.0]);
        let mut v = Vec::new();
        super::copy_into(&lit, &mut v).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
    }
}
