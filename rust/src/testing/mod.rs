//! Property-testing helper (the vendored set has no `proptest`).
//!
//! [`check`] runs a predicate over `n` randomized cases drawn through a
//! deterministic [`Gen`]; on failure it retries with progressively
//! "smaller" case indices (a lightweight shrink: the generator is
//! re-seeded with earlier indices, which tend to produce smaller sizes
//! because our generators scale size with `g.size_hint`), then panics
//! with the failing seed so the case replays exactly.

use crate::util::prng::{mix, Rng};

/// Randomized-case generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Grows with the case index: generators should scale sizes with it.
    pub size_hint: usize,
}

impl Gen {
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// Size in [1, size_hint+1] — the canonical "collection length".
    pub fn len(&mut self) -> usize {
        self.rng.range(1, self.size_hint + 2)
    }

    pub fn f32(&mut self, scale: f32) -> f32 {
        (self.rng.f32() * 2.0 - 1.0) * scale
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(scale)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Run `prop` over `n` random cases. Panics with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, n: usize, mut prop: F) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xED17_0001u64);
    for case in 0..n {
        let seed = mix(base, hash_name(name) ^ case as u64);
        let mut g = Gen { rng: Rng::new(seed), size_hint: 1 + case / 2 };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 replay with PROP_SEED={base}): {msg}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// assert_close for f32 slices with a combined abs/rel tolerance.
pub fn assert_close(got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "index {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 20, |g| {
            let n = g.len();
            assert!(n >= 1);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn check_reports_failure() {
        check("always-fails", 3, |_| panic!("boom"));
    }

    #[test]
    fn gen_deterministic_per_case() {
        let mut seen = Vec::new();
        check("det", 5, |g| seen.push(g.usize(0, 1000)));
        let mut seen2 = Vec::new();
        check("det", 5, |g| seen2.push(g.usize(0, 1000)));
        assert_eq!(seen, seen2);
    }

    #[test]
    fn assert_close_tolerates() {
        assert_close(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-6, 0.0);
    }

    #[test]
    #[should_panic(expected = "index 1")]
    fn assert_close_catches() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6, 1e-6);
    }
}
