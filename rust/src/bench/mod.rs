//! Micro-benchmark harness (the vendored set has no `criterion`).
//!
//! `cargo bench` targets are plain `harness = false` binaries built on
//! this module: warmup, calibrated iteration counts, median/mean/p10/p90
//! over timed batches, and a one-line report comparable across runs.
//! Used by `rust/benches/*.rs` (one bench per paper table/figure plus
//! the hot-path micro benches).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    /// Seconds per iteration.
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub iters: u64,
    /// Bytes moved per iteration (set via [`Bencher::bench_gbs`]);
    /// enables the GB/s column for memory-bound kernels.
    pub bytes: Option<u64>,
}

impl Stats {
    /// Effective memory throughput in GB/s (when `bytes` is known).
    pub fn gb_per_s(&self) -> Option<f64> {
        self.bytes
            .filter(|_| self.median > 0.0)
            .map(|b| b as f64 / self.median / 1e9)
    }

    pub fn report(&self) -> String {
        let gbs = self
            .gb_per_s()
            .map(|g| format!("  {g:>7.2} GB/s"))
            .unwrap_or_default();
        format!(
            "{:<44} {:>12}/iter  (p10 {:>10}, p90 {:>10}, n={}){gbs}",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.p10),
            fmt_duration(self.p90),
            self.iters
        )
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.3} s")
    }
}

/// Benchmark runner with a wall-clock budget per benchmark.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_batches: usize,
    results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // EDIT_BENCH_FAST=1 shrinks budgets (CI / smoke runs).
        let fast = std::env::var("EDIT_BENCH_FAST").is_ok();
        Self {
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            budget: Duration::from_millis(if fast { 100 } else { 1500 }),
            min_batches: 10,
            results: Vec::new(),
        }
    }

    /// Time `f` (called repeatedly); returns and records stats.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> Stats {
        self.bench_inner(name, None, f)
    }

    /// Like [`Self::bench`] for memory-bound kernels: `bytes` is the
    /// traffic per iteration, reported as an effective GB/s so kernel
    /// speedups land in the bench trajectory as bandwidth numbers.
    pub fn bench_gbs<F: FnMut()>(&mut self, name: &str, bytes: u64, f: F) -> Stats {
        self.bench_inner(name, Some(bytes), f)
    }

    fn bench_inner<F: FnMut()>(&mut self, name: &str, bytes: Option<u64>, mut f: F) -> Stats {
        // Warmup + calibration: find iters-per-batch ~ 1ms.
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.warmup {
            f();
            cal_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / cal_iters.max(1) as f64;
        let batch = ((1e-3 / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0u64;
        while start.elapsed() < self.budget || samples.len() < self.min_batches {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let stats = Stats {
            name: name.to_string(),
            mean: samples.iter().sum::<f64>() / samples.len() as f64,
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            iters: total_iters,
            bytes,
        };
        println!("{}", stats.report());
        self.results.push(stats.clone());
        stats
    }

    /// Run a one-shot measured section (for end-to-end table rows where
    /// repetition is too expensive); reports seconds.
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> (T, f64) {
        let t0 = Instant::now();
        let out = f();
        let secs = t0.elapsed().as_secs_f64();
        println!("{:<40} {:>12} (once)", name, fmt_duration(secs));
        self.results.push(Stats {
            name: name.to_string(),
            mean: secs,
            median: secs,
            p10: secs,
            p90: secs,
            iters: 1,
            bytes: None,
        });
        (out, secs)
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Write results as CSV next to the other experiment outputs.
    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        let mut w = crate::metrics::CsvWriter::create(
            path,
            &["name", "mean_s", "median_s", "p10_s", "p90_s", "iters", "gb_per_s"],
        )?;
        for s in &self.results {
            w.row(&[
                s.name.clone(),
                format!("{:.3e}", s.mean),
                format!("{:.3e}", s.median),
                format!("{:.3e}", s.p10),
                format!("{:.3e}", s.p90),
                s.iters.to_string(),
                s.gb_per_s().map(|g| format!("{g:.2}")).unwrap_or_default(),
            ])?;
        }
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        std::env::set_var("EDIT_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let mut x = 0u64;
        let s = b.bench("noop-ish", || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(s.median > 0.0 && s.median < 1e-3);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with("s"));
    }

    #[test]
    fn gbs_column_reported() {
        std::env::set_var("EDIT_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let buf = vec![1u8; 1024];
        let s = b.bench_gbs("touch-1k", 1024, || {
            std::hint::black_box(buf.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(s.gb_per_s().unwrap() > 0.0);
        assert!(s.report().contains("GB/s"));
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bencher::new();
        let (v, secs) = b.once("compute", || 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
