//! Metrics substrate: loss/PPL trackers, CSV/JSONL writers, run
//! summaries — everything the experiment harnesses use to emit the
//! paper's tables and figures as files under `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Exponential moving average (loss smoothing; also the EMA pieces of
/// the anomaly detector are built on the same update rule).
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Mean of the last `k` values — the paper reports "average of the last
/// 10 values" for final loss/PPL (Fig. 4 caption).
#[derive(Debug, Clone)]
pub struct TailMean {
    k: usize,
    buf: std::collections::VecDeque<f64>,
}

impl TailMean {
    pub fn new(k: usize) -> Self {
        // Full window preallocated: pushing never reallocates, which the
        // trainer's steady-state zero-allocation invariant relies on.
        Self { k, buf: std::collections::VecDeque::with_capacity(k) }
    }

    pub fn push(&mut self, x: f64) {
        if self.buf.len() == self.k {
            self.buf.pop_front();
        }
        self.buf.push_back(x);
    }

    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }
}

pub fn ppl(loss: f64) -> f64 {
    loss.exp()
}

/// Column-ordered CSV writer.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(Self { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> anyhow::Result<()> {
        anyhow::ensure!(values.len() == self.columns, "csv row arity");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> anyhow::Result<()> {
        let strs: Vec<String> = values.iter().map(|v| format_g(*v)).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

/// Compact float formatting for CSV/console (6 significant digits).
pub fn format_g(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let ax = x.abs();
    if (1e-4..1e7).contains(&ax) {
        let s = format!("{x:.6}");
        let s = s.trim_end_matches('0').trim_end_matches('.');
        s.to_string()
    } else {
        format!("{x:.4e}")
    }
}

/// Fixed-width console table (the `bench-table` output format).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:width$}  ", cell, width = widths[c]);
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 2 * cols;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// One per-replica synchronization event (the trainer records these
/// when `TrainConfig::trace_timeline` is on — the observability feed
/// for the event-driven A-EDiT path, where replicas sync at different
/// simulated times with per-worker staleness).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimelineEvent {
    pub replica: usize,
    /// Post-sync simulated clock of the replica (seconds).
    pub clock: f64,
    /// Global step counter at the time of the sync.
    pub global_step: u64,
    /// Anchor versions the replica missed since its previous sync.
    pub staleness: u64,
}

/// Per-replica sync-event timeline. Capacity is reserved up front when
/// tracing is enabled so steady-state recording never reallocates.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub events: Vec<TimelineEvent>,
}

impl Timeline {
    pub fn reserve(&mut self, n: usize) {
        self.events.reserve(n);
    }

    pub fn push(&mut self, e: TimelineEvent) {
        self.events.push(e);
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Write the trace as CSV (replica, clock, global_step, staleness).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w =
            CsvWriter::create(path, &["replica", "clock", "global_step", "staleness"])?;
        for e in &self.events {
            w.row(&[
                e.replica.to_string(),
                format_g(e.clock),
                e.global_step.to_string(),
                e.staleness.to_string(),
            ])?;
        }
        w.flush()
    }
}

/// Per-run loss/PPL tracker used by the trainer.
#[derive(Debug, Clone)]
pub struct RunTracker {
    pub losses: Vec<(u64, f64)>,
    pub val_ppl: Vec<(u64, f64)>,
    pub tail_loss: TailMean,
    pub tail_ppl: TailMean,
}

impl Default for RunTracker {
    fn default() -> Self {
        Self::new()
    }
}

impl RunTracker {
    pub fn new() -> Self {
        Self {
            losses: Vec::new(),
            val_ppl: Vec::new(),
            tail_loss: TailMean::new(10),
            tail_ppl: TailMean::new(10),
        }
    }

    /// Pre-size the train-loss trace so steady-state recording never
    /// reallocates (part of the trainer's zero-allocation invariant —
    /// see `coordinator::scratch`). The validation trace is left to grow
    /// on demand: it only fills when periodic eval runs, and evaluation
    /// itself allocates batches, so pre-reserving it would buy nothing.
    pub fn reserve(&mut self, expected_records: usize) {
        self.losses.reserve(expected_records);
    }

    pub fn record_loss(&mut self, step: u64, loss: f64) {
        self.losses.push((step, loss));
        self.tail_loss.push(loss);
    }

    pub fn record_val(&mut self, step: u64, val_loss: f64) {
        let p = ppl(val_loss);
        self.val_ppl.push((step, p));
        self.tail_ppl.push(p);
    }

    /// "Final" values as the paper reports them (mean of last 10).
    pub fn final_loss(&self) -> Option<f64> {
        self.tail_loss.mean()
    }

    pub fn final_ppl(&self) -> Option<f64> {
        self.tail_ppl.mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_behaviour() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(4.0), 4.0);
        assert_eq!(e.update(0.0), 2.0);
        assert_eq!(e.get(), Some(2.0));
    }

    #[test]
    fn tail_mean_window() {
        let mut t = TailMean::new(3);
        assert_eq!(t.mean(), None);
        for x in [1.0, 2.0, 3.0, 4.0] {
            t.push(x);
        }
        assert_eq!(t.mean(), Some(3.0)); // last 3: 2,3,4
    }

    #[test]
    fn ppl_of_zero_loss() {
        assert_eq!(ppl(0.0), 1.0);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("edit_train_test_csv");
        let path = dir.join("x.csv");
        let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
        w.row_f64(&[1.0, 2.5]).unwrap();
        w.row(&["2".into(), "hi".into()]).unwrap();
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,2.5\n2,hi\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn csv_arity_checked() {
        let dir = std::env::temp_dir().join("edit_train_test_csv2");
        let mut w = CsvWriter::create(dir.join("y.csv"), &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn format_g_cases() {
        assert_eq!(format_g(0.0), "0");
        assert_eq!(format_g(1.5), "1.5");
        assert_eq!(format_g(3.0), "3");
        assert!(format_g(1.23e-9).contains('e'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "tput"]);
        t.row(vec!["EDiT".into(), "4.81e5".into()]);
        t.row(vec!["Baseline".into(), "4.52e5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("method"));
        assert!(lines[2].starts_with("EDiT"));
    }

    #[test]
    fn timeline_csv_roundtrip() {
        let mut t = Timeline::default();
        t.reserve(2);
        t.push(TimelineEvent { replica: 1, clock: 2.5, global_step: 8, staleness: 0 });
        t.push(TimelineEvent { replica: 0, clock: 3.25, global_step: 8, staleness: 2 });
        let dir = std::env::temp_dir().join("edit_train_test_timeline");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "replica,clock,global_step,staleness\n1,2.5,8,0\n0,3.25,8,2\n"
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn run_tracker_final_values() {
        let mut r = RunTracker::new();
        for i in 0..20 {
            r.record_loss(i, 20.0 - i as f64);
        }
        // last 10 losses: 10..1 -> mean 5.5
        assert_eq!(r.final_loss(), Some(5.5));
        r.record_val(19, 0.0);
        assert_eq!(r.final_ppl(), Some(1.0));
    }
}
