#!/usr/bin/env bash
# Multi-process smoke for the socket collective backend: start a real
# rendezvous hub, join two real `edit-train worker` OS processes over
# loopback TCP, run the EDiT driver rounds, and diff the final anchor
# digests against the in-process ThreadComm reference (`worker --local`).
# The digests must be BITWISE identical — this is the acceptance
# property of the fold-order contract (docs/WIRE_PROTOCOL.md §5) checked
# on actual processes and actual sockets, not threads. Runs both wire
# payload lanes (f32 and int8), each in two modes: the blocking
# single-module schedule, and the overlapped 4-module schedule
# (--modules 4 --overlap — pipelined Contribute frames in flight while
# the next module computes, WIRE_PROTOCOL.md §4.2) diffed against the
# BLOCKING single-process reference at the same module layout.
#
# Usage: scripts/smoke_multiproc.sh  (expects rust/target/release built;
# override the binary with BIN=path).
set -euo pipefail
cd "$(dirname "$0")/../rust"

BIN=${BIN:-./target/release/edit-train}
if [[ ! -x "$BIN" ]]; then
    echo "error: $BIN is missing — run 'cargo build --release' first" >&2
    exit 1
fi

WORKDIR=$(mktemp -d)
PIDS=()
cleanup() {
    for pid in "${PIDS[@]:-}"; do
        kill "$pid" 2>/dev/null || true
    done
    rm -rf "$WORKDIR"
}
trap cleanup EXIT

fail=0
for payload in f32 int8; do
for mode in blocking overlapped; do
    out="$WORKDIR/$payload-$mode"
    mkdir -p "$out"
    # Worker args per mode. The overlapped leg runs the 4-module
    # nonblocking schedule over the socket (pipelined frames); the
    # local reference deliberately stays BLOCKING at the same module
    # layout — the overlapped schedule must reproduce its digest.
    wargs=(--payload "$payload")
    largs=(--payload "$payload")
    if [[ "$mode" == overlapped ]]; then
        wargs+=(--modules 4 --overlap)
        largs+=(--modules 4)
    fi

    # Hub on an ephemeral port; parse the address it prints.
    "$BIN" rendezvous --bind 127.0.0.1:0 --world 2 >"$out/hub.log" 2>&1 &
    hub_pid=$!
    PIDS+=("$hub_pid")
    addr=""
    for _ in $(seq 1 200); do
        addr=$(sed -n 's/^rendezvous listening on \([^ ]*\).*/\1/p' "$out/hub.log" | head -n1)
        [[ -n "$addr" ]] && break
        if ! kill -0 "$hub_pid" 2>/dev/null; then
            echo "smoke_multiproc: hub died before binding ($payload/$mode)" >&2
            cat "$out/hub.log" >&2
            exit 1
        fi
        sleep 0.05
    done
    if [[ -z "$addr" ]]; then
        echo "smoke_multiproc: hub never printed its address ($payload/$mode)" >&2
        exit 1
    fi

    # Two real worker processes against the hub.
    "$BIN" worker --join "$addr" "${wargs[@]}" >"$out/w0.log" 2>&1 &
    w0=$!
    PIDS+=("$w0")
    "$BIN" worker --join "$addr" "${wargs[@]}" >"$out/w1.log" 2>&1 &
    w1=$!
    PIDS+=("$w1")
    for pid in "$w0" "$w1" "$hub_pid"; do
        if ! wait "$pid"; then
            echo "smoke_multiproc: pid $pid exited non-zero ($payload/$mode)" >&2
            tail -v -n +1 "$out"/*.log >&2
            exit 1
        fi
    done

    # In-process ThreadComm reference (blocking) at the same config.
    "$BIN" worker --local 2 "${largs[@]}" >"$out/local.log" 2>&1

    sock0=$(grep -o 'digest=0x[0-9a-f]*' "$out/w0.log" | head -n1)
    sock1=$(grep -o 'digest=0x[0-9a-f]*' "$out/w1.log" | head -n1)
    ref=$(grep -o 'digest=0x[0-9a-f]*' "$out/local.log" | sort -u)
    if [[ -z "$sock0" || -z "$sock1" || -z "$ref" ]]; then
        echo "smoke_multiproc: missing digest line ($payload/$mode)" >&2
        tail -v -n +1 "$out"/*.log >&2
        exit 1
    fi
    if [[ $(wc -l <<<"$ref") -ne 1 ]]; then
        echo "smoke_multiproc: local ranks disagree ($payload/$mode): $ref" >&2
        fail=1
    elif [[ "$sock0" != "$ref" || "$sock1" != "$ref" ]]; then
        echo "smoke_multiproc: $payload/$mode digests diverge: sock0=$sock0 sock1=$sock1 local=$ref" >&2
        fail=1
    else
        echo "smoke_multiproc: $payload/$mode OK — 2-process socket run == blocking in-process reference ($ref)"
    fi
done
done

# Start a hub on an ephemeral port, logging into $1/hub.log; sets
# `addr` and `hub_pid`.
start_hub() {
    "$BIN" rendezvous --bind 127.0.0.1:0 --world 2 >"$1/hub.log" 2>&1 &
    hub_pid=$!
    PIDS+=("$hub_pid")
    addr=""
    for _ in $(seq 1 200); do
        addr=$(sed -n 's/^rendezvous listening on \([^ ]*\).*/\1/p' "$1/hub.log" | head -n1)
        [[ -n "$addr" ]] && break
        if ! kill -0 "$hub_pid" 2>/dev/null; then
            echo "smoke_multiproc: hub died before binding ($1)" >&2
            cat "$1/hub.log" >&2
            exit 1
        fi
        sleep 0.05
    done
    if [[ -z "$addr" ]]; then
        echo "smoke_multiproc: hub never printed its address ($1)" >&2
        exit 1
    fi
}

# ---------------------------------------------------------------------------
# Chaos leg: the same 2-process run under a seeded wire-fault plan —
# rank 1 loses its TCP link at round 1 (reconnect + same-seq replay,
# WIRE_PROTOCOL.md §6) and rank 0 stalls 30ms at round 2. The final
# digest must STILL be bitwise identical to the clean in-process
# reference: chaos may cost wall-clock, never bits.
# ---------------------------------------------------------------------------
out="$WORKDIR/chaos"
mkdir -p "$out"
plan='netdrop@1:1,netdelay@2:0:30'
start_hub "$out"
"$BIN" worker --join "$addr" --rounds 4 --net-plan "$plan" >"$out/w0.log" 2>&1 &
w0=$!
PIDS+=("$w0")
"$BIN" worker --join "$addr" --rounds 4 --net-plan "$plan" >"$out/w1.log" 2>&1 &
w1=$!
PIDS+=("$w1")
for pid in "$w0" "$w1" "$hub_pid"; do
    if ! wait "$pid"; then
        echo "smoke_multiproc: pid $pid exited non-zero (chaos)" >&2
        tail -v -n +1 "$out"/*.log >&2
        exit 1
    fi
done
"$BIN" worker --local 2 --rounds 4 >"$out/local.log" 2>&1
sock0=$(grep -o 'digest=0x[0-9a-f]*' "$out/w0.log" | head -n1)
sock1=$(grep -o 'digest=0x[0-9a-f]*' "$out/w1.log" | head -n1)
ref=$(grep -o 'digest=0x[0-9a-f]*' "$out/local.log" | sort -u)
if [[ $(wc -l <<<"$ref") -ne 1 || -z "$sock0" || "$sock0" != "$ref" || "$sock1" != "$ref" ]]; then
    echo "smoke_multiproc: chaos digests diverge: sock0=$sock0 sock1=$sock1 local=$ref" >&2
    tail -v -n +1 "$out"/*.log >&2
    fail=1
elif ! grep -qh 'reconnects=[1-9]' "$out/w0.log" "$out/w1.log"; then
    echo "smoke_multiproc: chaos run never exercised the reconnect path" >&2
    tail -v -n +1 "$out"/*.log >&2
    fail=1
else
    echo "smoke_multiproc: chaos OK — netdrop+reconnect run == clean in-process reference ($ref)"
fi

# ---------------------------------------------------------------------------
# Restore leg: run 3 of 5 rounds with round-boundary checkpoints, kill
# the world (processes exit), then restore both ranks against a brand
# new hub and finish rounds 3..5. The digest must equal the clean
# uninterrupted 5-round reference — kill + restore replays bitwise.
# ---------------------------------------------------------------------------
out="$WORKDIR/restore"
mkdir -p "$out/ckpt"
start_hub "$out"
p1_pids=()
for i in 0 1; do
    "$BIN" worker --join "$addr" --rounds 3 --checkpoint-every 3 \
        --checkpoint-dir "$out/ckpt" >"$out/p1-w$i.log" 2>&1 &
    p1_pids+=("$!")
    PIDS+=("$!")
done
for pid in "${p1_pids[@]}" "$hub_pid"; do
    if ! wait "$pid"; then
        echo "smoke_multiproc: restore phase-1 pid $pid failed" >&2
        tail -v -n +1 "$out"/*.log >&2
        exit 1
    fi
done
start_hub "$out"
p2_pids=()
for i in 0 1; do
    "$BIN" worker --join "$addr" --rounds 5 \
        --restore "$out/ckpt/ckpt-rank{rank}-round3.bin" >"$out/p2-w$i.log" 2>&1 &
    p2_pids+=("$!")
    PIDS+=("$!")
done
for pid in "${p2_pids[@]}" "$hub_pid"; do
    if ! wait "$pid"; then
        echo "smoke_multiproc: restore phase-2 pid $pid failed" >&2
        tail -v -n +1 "$out"/*.log >&2
        exit 1
    fi
done
"$BIN" worker --local 2 --rounds 5 >"$out/local.log" 2>&1
res=$(grep -h -o 'digest=0x[0-9a-f]*' "$out/p2-w0.log" "$out/p2-w1.log" | sort -u)
ref=$(grep -o 'digest=0x[0-9a-f]*' "$out/local.log" | sort -u)
if [[ $(wc -l <<<"$res") -ne 1 || $(wc -l <<<"$ref") -ne 1 || "$res" != "$ref" ]]; then
    echo "smoke_multiproc: restore digests diverge: restored=$res local=$ref" >&2
    tail -v -n +1 "$out"/*.log >&2
    fail=1
else
    echo "smoke_multiproc: restore OK — kill at round 3 + restore replays bitwise ($ref)"
fi

if [[ "$fail" -ne 0 ]]; then
    echo "smoke_multiproc: FAILED — socket backend diverges from ThreadComm" >&2
    exit 1
fi
echo "smoke_multiproc: OK"
