#!/usr/bin/env bash
# One-command verification gate (also `make verify`):
#   tier-1:  cargo build --release && cargo test -q
#   docs:    RUSTDOCFLAGS=-D warnings cargo doc --no-deps (broken
#            intra-doc links and bad doc syntax fail the gate)
#   smoke:   fig5-trainer straggler cross-validation (real trainer)
#   chaos:   seeded fault schedules, kill-at-midpoint + restore must
#            replay bitwise (writes results/fault_recovery.csv)
#   multiproc: scripts/smoke_multiproc.sh — rendezvous hub + 2 real
#            worker processes over loopback TCP, final anchor digest
#            diffed bitwise against the in-process ThreadComm reference
#   hygiene: cargo fmt --check, cargo clippy -D warnings (skipped with a
#            notice when the components are not installed — CI installs
#            them explicitly so the skips never trigger there)
#
# Flags:
#   --quick  build (incl. --examples, so example targets can't bit-rot)
#            + test + doc gate only (no smokes, no fmt/clippy) — the
#            fast CI leg and the pre-push sanity loop.
set -euo pipefail
SCRIPT_DIR=$(cd "$(dirname "$0")" && pwd)
cd "$SCRIPT_DIR/../rust"

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *)
            echo "verify.sh: unknown option '$arg' (supported: --quick)" >&2
            exit 2
            ;;
    esac
done

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Rustdoc gate: the Collective trait contract, the wire-protocol frame
# docs, and their intra-doc links are load-bearing documentation —
# breaking them breaks the gate, in both CI legs.
echo '== RUSTDOCFLAGS="-D warnings" cargo doc --no-deps =='
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

if [[ "$QUICK" == 1 ]]; then
    # Example targets are part of the quick gate so they can't bit-rot
    # (the full gate covers them via `clippy --all-targets`).
    echo "== cargo build --release --examples =="
    cargo build --release --examples
    echo "verify (--quick): OK"
    exit 0
fi

# Straggler smoke: drive the REAL trainer (event-driven per-replica
# core) through the consistent + random straggler scenarios and
# cross-validate the A-EDiT : EDiT speedup against the analytic
# simulator. Seconds-scale; falls back to the synthetic stub model when
# AOT artifacts are absent, so it runs on a clean box. The harness
# itself enforces the >=1.5x consistent-straggler acceptance bound.
BIN=./target/release/edit-train
if [[ ! -x "$BIN" ]]; then
    echo "error: $BIN is missing or not executable." >&2
    echo "       The release build above should have produced it — stale" >&2
    echo "       checkout or a renamed bin target? Run 'cargo build --release'" >&2
    echo "       inside rust/ and check [[bin]] in rust/Cargo.toml." >&2
    exit 1
fi
mkdir -p results
echo "== straggler smoke (real trainer, async A-EDiT path) =="
"$BIN" simulate --exp fig5-trainer --steps 32 --tau 4

# Chaos smoke: every layer-wise preset x sharding mode under a seeded
# crash/rejoin schedule, run twice — uninterrupted vs killed at the
# midpoint round + restored from the checkpoint — and diffed field by
# field plus final-checkpoint bytes. Any divergence exits non-zero;
# the per-run rows land in results/fault_recovery.csv (a CI artifact).
echo "== chaos smoke (fault injection + kill/restore bitwise replay) =="
"$BIN" chaos --steps 32 --tau 4 --seeds 2 --pairs 2

# Multi-process smoke: rendezvous hub + two real `edit-train worker`
# processes over loopback TCP; their final anchor digests must be
# bitwise identical to the in-process ThreadComm reference, on both
# wire payload lanes (f32 and int8).
echo "== multi-process smoke (socket backend, 2 workers over loopback) =="
"$SCRIPT_DIR/smoke_multiproc.sh"

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt not installed; skipping format check =="
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy not installed; skipping lint =="
fi

echo "verify: OK"
