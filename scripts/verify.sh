#!/usr/bin/env bash
# One-command verification gate (also `make verify`):
#   tier-1:  cargo build --release && cargo test -q
#   hygiene: cargo fmt --check, cargo clippy -D warnings (skipped with a
#            notice when the components are not installed)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt not installed; skipping format check =="
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy not installed; skipping lint =="
fi

echo "verify: OK"
