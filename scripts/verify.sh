#!/usr/bin/env bash
# One-command verification gate (also `make verify`):
#   tier-1:  cargo build --release && cargo test -q
#   hygiene: cargo fmt --check, cargo clippy -D warnings (skipped with a
#            notice when the components are not installed)
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Straggler smoke: drive the REAL trainer (event-driven per-replica
# core) through the consistent + random straggler scenarios and
# cross-validate the A-EDiT : EDiT speedup against the analytic
# simulator. Seconds-scale; falls back to the synthetic stub model when
# AOT artifacts are absent, so it runs on a clean box. The harness
# itself enforces the >=1.5x consistent-straggler acceptance bound.
echo "== straggler smoke (real trainer, async A-EDiT path) =="
./target/release/edit-train simulate --exp fig5-trainer --steps 32 --tau 4

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== cargo fmt not installed; skipping format check =="
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -D warnings =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== cargo clippy not installed; skipping lint =="
fi

echo "verify: OK"
